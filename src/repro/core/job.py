"""Job records managed by the ELIS frontend (paper §4.1).

A *job* is the scheduler-internal record of one prompt: its text/tokens, the
backend node it was balanced onto, its current priority (predicted remaining
tokens), the partial response accumulated over scheduling iterations, and the
timestamps from which JCT / queuing delay are computed.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Optional

if TYPE_CHECKING:
    from repro.core.api import TokenChunk


class JobState(enum.Enum):
    WAITING = "waiting"      # in JobPool, not yet dispatched this iteration
    RUNNING = "running"      # inside a backend batch
    PREEMPTED = "preempted"  # evicted mid-generation; resumes from tokens
    FINISHED = "finished"
    CANCELLED = "cancelled"  # caller cancelled; slot released
    EXPIRED = "expired"      # deadline passed before completion


#: states a job never leaves
TERMINAL_STATES = frozenset(
    {JobState.FINISHED, JobState.CANCELLED, JobState.EXPIRED}
)


@dataclass
class Job:
    job_id: int
    prompt: str
    prompt_tokens: List[int]
    arrival_time: float
    #: ground-truth response length — known to the generator/oracle only
    true_output_len: int = 0
    #: precomputed response token stream (simulator replays it)
    output_tokens: List[int] = field(default_factory=list)

    node: int = -1
    state: JobState = JobState.WAITING
    #: scheduler priority = predicted remaining tokens (lower runs first)
    priority: Optional[float] = None
    #: prediction history, one entry per scored scheduling iteration
    #: (paper Fig. 2; every window at ``repredict_every=1``)
    predictions: List[float] = field(default_factory=list)
    #: ``tokens_generated`` at the last fresh score — between full re-scores
    #: (``SchedulerConfig.repredict_every``) the scheduler reuses
    #: ``priority - (tokens_generated - tokens_at_last_score)``
    tokens_at_last_score: Optional[int] = None
    #: expected remaining length from the last score.  Equal to ``priority``
    #: unless risk-aware scoring is on (then ``priority`` is an upper
    #: quantile); the cluster layer's predicted-work accounting always
    #: consumes this expectation, never the quantile
    expected_remaining: Optional[float] = None
    #: (tokens_generated, expected_remaining) at each scored window — the
    #: realised-vs-predicted trace behind per-request prediction-error
    #: stats (``Response.pred_mae`` / ``pred_bias``); only populated by
    #: length-predicting policies (SJF/ISRTF)
    pred_trace: List[tuple] = field(default_factory=list)

    generated: List[int] = field(default_factory=list)
    finished: bool = False
    #: tokens of context currently materialised in the backend's KV cache
    #: for this job (prompt + generated).  Mid-chunked-prefill it lags
    #: ``len(prompt_tokens)``; a recompute-eviction resets it to 0 while a
    #: KV swap-out preserves it.  ``prefill_debt`` (scheduler) and the
    #: swap-vs-recompute break-even both read this cursor.
    prefilled_tokens: int = 0

    # request-lifecycle fields (populated from api.RequestOptions)
    #: absolute deadline on the serving clock; None = no deadline
    deadline: Optional[float] = None
    tenant: str = "default"
    #: coarse priority band (lower outranks higher regardless of length)
    priority_class: int = 0
    #: caller asked for cancellation; honoured at the next window boundary
    cancel_requested: bool = False
    #: retain per-iteration TokenChunks for a streaming consumer (bounded
    #: memory: non-streaming jobs keep only the flat ``generated`` list)
    stream: bool = False
    #: per-iteration token emissions, populated only when ``stream`` is set
    chunks: List["TokenChunk"] = field(default_factory=list)

    # timing
    first_dispatch_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    #: cumulative time spent waiting in the JobPool while not executing
    queuing_delay: float = 0.0
    last_enqueue_time: Optional[float] = None
    n_preemptions: int = 0
    #: times the rebalancer moved this job to another node while queued
    n_migrations: int = 0
    n_iterations: int = 0

    @property
    def tokens_generated(self) -> int:
        return len(self.generated)

    @property
    def true_remaining(self) -> int:
        return max(self.true_output_len - self.tokens_generated, 0)

    def jct(self) -> float:
        assert self.finish_time is not None
        return self.finish_time - self.arrival_time

    def record_enqueue(self, now: float) -> None:
        self.last_enqueue_time = now

    def record_dispatch(self, now: float) -> None:
        if self.first_dispatch_time is None:
            self.first_dispatch_time = now
        if self.last_enqueue_time is not None:
            self.queuing_delay += max(now - self.last_enqueue_time, 0.0)
            self.last_enqueue_time = None
