"""Response-length predictors (paper §3.2–3.3, §4.2) — the distribution-aware
``LengthPredictor`` subsystem.

Every predictor returns typed :class:`LengthPrediction` results (point
estimate, spread, quantile ladder) from ONE batched entry point::

    predictions = predictor.predict(pool)      # list[LengthPrediction]

and accepts online feedback from the serving loop::

    predictor.observe(job, actual_remaining)   # every window / finish

The legacy scalar protocol (``init(job)`` / ``iter(job)``, Algorithm 1
lines 11–14) survives as thin deprecation shims on the base class — both
return ``predict([job])[0].mean`` — so old callers keep working while new
consumers (risk-aware ISRTF, work-aware placement, calibration benchmarks)
read the full distribution.

Base predictors:

* :class:`BGEPredictor` — the paper's model: a (frozen) BGE-style encoder +
  8 fully-connected layers (hidden 1024, ReLU) regressing the *remaining*
  output length from ``[CLS] prompt [SEP] partial-output``.  Implemented and
  trained fully in JAX; the encoder can be frozen (paper §3.2) or trained
  end-to-end.  ``fit`` additionally estimates the log-space residual spread
  on the training samples, so quantiles are available out of the box.
* :class:`OraclePredictor` — ground-truth remaining length (degenerate
  distribution; the paper's SJF "ideal" upper bound).
* :class:`NoisyOraclePredictor` — truth corrupted by step-dependent
  lognormal noise whose σ decays with the iteration index, calibrated to the
  paper's Fig. 2(b) MAE-vs-step curve; its quantile ladder is the analytic
  lognormal posterior, so risk-aware scoring needs no extra RNG draws.

Calibration wrappers (compose over any base via :func:`make_predictor`):

* :class:`EMADebiasedPredictor` — tracks the multiplicative bias
  ``predicted / actual`` (optionally per iteration step, Fig. 2(b) says the
  error profile is step-dependent) as an EMA of log-ratios and divides it
  back out of every prediction.
* :class:`ConformalPredictor` — distribution-free quantiles from a rolling
  window of multiplicative residuals (split-conformal with the finite-sample
  ``ceil((n+1)q)/n`` correction), optionally Mondrian-bucketed by step.

Learning-to-rank (the two-head subsystem):

* ``PredictorConfig(ranking=RankingConfig(...))`` grows a sibling *ranking
  head* on the shared BGE trunk, trained jointly with the regression head
  (pairwise-margin or listwise loss from ``repro.models.objective``).  Its
  score lands on :attr:`LengthPrediction.rank_score` — pool-ordering only;
  the calibrated ``mean`` keeps feeding ``Job.expected_remaining`` and all
  cluster predicted-work accounting.
* :class:`RankedPredictor` — the serving adapter (``make_predictor
  ("ranked", bge=...)``): one fused dispatch fills both heads, and
  ``observe()`` harvests completed-job pairs from a rolling window into
  deterministic online head updates (CANCELLED/EXPIRED stay censored and
  never form pairs).  Composes under the calibration wrappers, which
  adjust magnitudes and pass ``rank_score`` through untouched.

The scheduler's hot path stays a single *shape-bucketed* dispatch per
scheduling window (batch padded to power-of-two buckets, sequence to the
``seq_bucket`` ladder); ``BGEPredictor.num_traces`` exposes the compile
count and ``num_dispatches`` the dispatch count for the recompile-storm
guard in ``benchmarks/scheduler_overhead.py``.
"""
from __future__ import annotations

import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field, replace
from typing import (
    Deque,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.job import TERMINAL_STATES, Job, JobState
from repro.core.metrics import kendall_tau
from repro.data.dataset import (
    WINDOW,
    StepSample,
    batch_bucket,
    seq_bucket,
)
from repro.data.tokenizer import CLS_ID, PAD_ID, SEP_ID
from repro.models import encoder as E
from repro.models.layers import dense_init
from repro.models.objective import RankingConfig, ranking_loss
from repro.training import AdamWConfig, train


class Predictor(Protocol):
    """Deprecated scalar protocol (pre-LengthPredictor).  New code should
    type against :class:`LengthPredictor` and call ``predict``/``observe``;
    these two methods remain only so old annotations keep resolving."""

    def init(self, job: Job) -> float: ...
    def iter(self, job: Job) -> float: ...


# --------------------------------------------------------------------------- #
# LengthPrediction — the typed result
# --------------------------------------------------------------------------- #


#: quantile ladder every distribution-aware predictor materialises; the
#: scheduler interpolates between rungs for other risk levels
QUANTILE_GRID: Tuple[float, ...] = (0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99)


def _norm_ppf(q: float) -> float:
    """Standard-normal inverse CDF (Acklam's rational approximation,
    |rel err| < 1.2e-9 — plenty for risk quantiles; avoids a scipy dep)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow = 0.02425
    if q < plow:
        u = math.sqrt(-2.0 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u
                + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1)
    if q > 1 - plow:
        u = math.sqrt(-2.0 * math.log(1 - q))
        return -(((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u
                 + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1)
    u = q - 0.5
    r = u * u
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
            + a[5]) * u / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                            + b[4]) * r + 1)


#: z-values for the grid, computed once — ladder construction sits on the
#: scheduling hot path (every scored job, every window)
_Z_GRID: Tuple[float, ...] = tuple(_norm_ppf(q) for q in QUANTILE_GRID)


def _lognormal_ladder(mean: float, mu: float,
                      s: float) -> Tuple[Tuple[float, float], ...]:
    """Quantile ladder of ``mean * LogNormal(mu, s)`` on the grid."""
    return tuple((q, mean * math.exp(mu + s * z))
                 for q, z in zip(QUANTILE_GRID, _Z_GRID))


@dataclass(frozen=True)
class LengthPrediction:
    """One job's predicted remaining length, as a distribution.

    ``mean`` is the point estimate every legacy consumer ranked on (for a
    stochastic predictor it is the *draw*, not the posterior mean — trace
    compatibility with the scalar API is exact).  ``quantiles`` is a sorted
    ``(q, value)`` ladder; :meth:`quantile` interpolates between rungs and
    falls back to a normal approximation from ``std`` (degenerate at the
    mean when ``std == 0``).
    """

    mean: float
    std: float = 0.0
    quantiles: Tuple[Tuple[float, float], ...] = ()
    #: ranking-head score: a token-scale pseudo-length whose ORDER across a
    #: pool is meaningful but whose magnitude is uncalibrated.  The
    #: scheduler orders on it under ``SchedulerConfig.rank_by =
    #: "rank_score"``; ``expected_remaining`` / predicted-work accounting
    #: never read it.  None for single-head predictors.
    rank_score: Optional[float] = None

    def quantile(self, q: float) -> float:
        """The q-th quantile of the predicted remaining length."""
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        lad = self.quantiles
        if lad:
            if q <= lad[0][0]:
                return lad[0][1]
            for (q0, v0), (q1, v1) in zip(lad, lad[1:]):
                if q <= q1:
                    w = (q - q0) / (q1 - q0)
                    return v0 + w * (v1 - v0)
            return lad[-1][1]
        if self.std > 0.0:
            return max(self.mean + _norm_ppf(q) * self.std, 0.0)
        return self.mean


# --------------------------------------------------------------------------- #
# LengthPredictor — the base class
# --------------------------------------------------------------------------- #


class LengthPredictor:
    """Distribution-aware predictor base.

    Subclasses implement EITHER ``predict_jobs(jobs) -> array`` (one batched
    dispatch of point estimates — the BGE path) OR ``_point(job) -> float``
    (per-job point estimate, e.g. the oracles), plus optionally
    ``_prediction(job, mean)`` to attach spread/quantiles.  ``observe`` is a
    no-op here; calibration wrappers override it to consume feedback.

    ``init``/``iter`` are the deprecated scalar shims (Algorithm 1's
    surface): both return ``predict([job])[0].mean``.
    """

    def predict(self, jobs: Sequence[Job]) -> List[LengthPrediction]:
        """Batched prediction for a scheduling pool — ONE dispatch when the
        underlying model supports it.  For stochastic predictors the draw
        order is the pool order (scoring order), which keeps drain-once
        traces bit-identical to the legacy per-job ``init``/``iter`` path."""
        jobs = list(jobs)
        if not jobs:
            return []
        pj = getattr(self, "predict_jobs", None)
        if pj is not None:
            means = [float(m) for m in pj(jobs)]
        else:
            means = [float(self._point(j)) for j in jobs]
        return [self._prediction(j, m) for j, m in zip(jobs, means)]

    def observe(self, job: Job, actual_remaining: float) -> None:
        """Online feedback: ``job`` has ``actual_remaining`` ground-truth
        tokens left *now*.  The serving loop calls this on every window where
        truth is known (trace replay / simulation), on every FINISH
        (``actual_remaining == 0``), and on CANCELLED/EXPIRED terminations
        (whose censored lengths calibrators must discard).  No-op for raw
        predictors."""

    # -- helpers subclasses provide ------------------------------------- #
    def _point(self, job: Job) -> float:  # pragma: no cover - abstract-ish
        raise NotImplementedError(
            f"{type(self).__name__} must implement _point or predict_jobs")

    def _prediction(self, job: Job, mean: float) -> LengthPrediction:
        return LengthPrediction(mean=mean)

    # -- deprecated scalar shims ---------------------------------------- #
    def init(self, job: Job) -> float:
        """Deprecated: use ``predict([job])[0]``."""
        return self.predict([job])[0].mean

    def iter(self, job: Job) -> float:
        """Deprecated: use ``predict([job])[0]``."""
        return self.predict([job])[0].mean


def predict_lengths(pred, jobs: Sequence[Job]) -> List[LengthPrediction]:
    """Adapt any predictor — new or legacy — to ``list[LengthPrediction]``.

    The scheduler's single entry point: a :class:`LengthPredictor` answers
    through its batched ``predict``; a legacy object with only
    ``predict_jobs`` or ``init``/``iter`` is wrapped into degenerate
    point-mass predictions (same call order as the old scoring loop)."""
    jobs = list(jobs)
    if not jobs:
        return []
    p = getattr(pred, "predict", None)
    if p is not None:
        return list(p(jobs))
    pj = getattr(pred, "predict_jobs", None)
    if pj is not None:
        return [LengthPrediction(mean=float(m)) for m in pj(jobs)]
    out = []
    for j in jobs:
        v = pred.init(j) if j.priority is None else pred.iter(j)
        out.append(LengthPrediction(mean=float(v)))
    return out


# --------------------------------------------------------------------------- #
# Oracle predictors
# --------------------------------------------------------------------------- #


class OraclePredictor(LengthPredictor):
    """Ground-truth remaining length (the SJF 'ideal' bound)."""

    def _point(self, job: Job) -> float:
        return float(job.true_remaining)


@dataclass
class NoisyOraclePredictor(LengthPredictor):
    """truth * lognormal(0, sigma_k) * bias;  sigma_k = sigma0 * decay^k.

    Defaults calibrated against our trained BGE predictor's per-step relative
    error (see benchmarks/fig2_iterative_mae.py): step-0 MAE/mean ≈ 0.45
    falling toward ≈ 0.25 by step 4 — matching the paper's Fig. 2(b) shape.

    ``bias`` injects a systematic multiplicative mis-calibration (< 1 =
    underestimates, the head-of-line-blocking direction) for the calibration
    benchmarks; the default 1.0 is bit-exact with the unbiased predictor.
    The quantile ladder is the analytic posterior of the truth given the
    draw (lognormal), so risk-aware consumers cost no extra RNG draws and
    the draw sequence — one per job, in scoring order — is untouched.
    """

    # calibrated to the trained BGE predictor's relative error per step
    # (benchmarks/fig2_iterative_mae.py): ~0.5 at step 0 -> ~0.3 floor
    sigma0: float = 0.50
    decay: float = 0.90
    sigma_floor: float = 0.30
    seed: int = 0
    #: systematic multiplicative bias applied to every prediction
    bias: float = 1.0
    _rng: np.random.RandomState = field(default=None, repr=False)

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    def _sigma(self, step: int) -> float:
        return max(self.sigma0 * self.decay ** step, self.sigma_floor)

    def _point(self, job: Job) -> float:
        step = job.tokens_generated // WINDOW
        s = self._sigma(step)
        noise = self._rng.lognormal(mean=-0.5 * s * s, sigma=s)
        return max(float(job.true_remaining) * noise * self.bias, 1.0)

    def _prediction(self, job: Job, mean: float) -> LengthPrediction:
        # posterior of truth given the draw m = truth * noise:
        # truth = m / noise ~ m * LogNormal(s^2/2, s), so the q-quantile is
        # m * exp(s^2/2 + s * z_q) and the std carries the full
        # exp(mu + s^2/2) = exp(s^2) factor
        s = self._sigma(job.tokens_generated // WINDOW)
        ladder = _lognormal_ladder(mean, 0.5 * s * s, s)
        std = mean * math.exp(s * s) * math.sqrt(max(math.expm1(s * s), 0.0))
        return LengthPrediction(mean=mean, std=std, quantiles=ladder)


# --------------------------------------------------------------------------- #
# BGE predictor (the paper's model)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class PredictorConfig:
    # default_factory, not a shared class-level instance: EncoderArchConfig
    # is frozen today, but a shared default is the same hazard class as the
    # EngineConfig() bug PR 1 fixed — every PredictorConfig() would alias
    # one object, and any future mutable field on it would couple them all
    encoder: E.EncoderArchConfig = field(default_factory=E.EncoderArchConfig)
    n_fc_layers: int = 8           # paper: eight FC layers
    fc_hidden: int = 1024          # paper: hidden dim 1024
    max_len: int = 256
    freeze_encoder: bool = False   # paper freezes pretrained BGE; ours trains
    lr: float = 1e-4               # paper: 1e-4
    predict_log: bool = True       # regress log(remaining) (skew-friendly)
    #: presence enables the sibling learning-to-rank head on the shared
    #: trunk (trained jointly; see repro.models.objective.RankingConfig).
    #: None keeps the parameter tree and every trace bit-identical to the
    #: single-head predictor.
    ranking: Optional[RankingConfig] = None


def init_head(key, in_dim: int, hidden: int, n_layers: int,
              init_log_len: float = 4.8) -> Dict:
    """8-FC regression head.  The final bias starts at log(median length)
    (~e^4.8 ≈ 120 tokens) so the log-space prediction begins at a sane prior
    and gradients flow from step 0 (a zero-init bias puts every prediction at
    the clip boundary, where the gradient dies)."""
    ks = jax.random.split(key, n_layers)
    layers = []
    d = in_dim
    for i in range(n_layers - 1):
        layers.append({"w": dense_init(ks[i], d, hidden),
                       "b": jnp.zeros((hidden,))})
        d = hidden
    layers.append({"w": dense_init(ks[-1], d, 1),
                   "b": jnp.full((1,), init_log_len)})
    return {"layers": layers}


def apply_head(head: Dict, x: jnp.ndarray) -> jnp.ndarray:
    for lp in head["layers"][:-1]:
        x = jax.nn.relu(x @ lp["w"] + lp["b"])
    last = head["layers"][-1]
    return (x @ last["w"] + last["b"])[..., 0]


class BGEPredictor(LengthPredictor):
    """Encoder + FC-head length regressor with iterative refinement.

    ``fit`` additionally estimates the model's log-space residual
    distribution (mean + spread of ``log(actual / predicted)``) on the
    training samples, which :meth:`_prediction` turns into a lognormal
    quantile ladder — so a freshly trained predictor supports risk-aware
    scoring without any serving-time feedback.  The ``mean`` stays the raw
    point estimate (quantiles are only consumed when a risk level is set),
    so legacy traces are unchanged.
    """

    def __init__(self, cfg: Optional[PredictorConfig] = None, seed: int = 0):
        # None-default: a shared PredictorConfig() instance as the default
        # argument would alias one config object across every predictor
        self.cfg = cfg = cfg if cfg is not None else PredictorConfig()
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        self.params = {
            "encoder": E.init_encoder(k1, cfg.encoder),
            # paper §4.2: mean-pooled token embeddings feed the FC stack;
            # we concat [CLS; mean] (CLS is what §3.2 probes)
            "head": init_head(k2, 2 * cfg.encoder.d_model, cfg.fc_hidden,
                              cfg.n_fc_layers),
        }
        if cfg.ranking is not None:
            # sibling ranking head on the shared trunk; keyed off a fold of
            # the root key so the encoder/head init above stays bit-identical
            # to the single-head model at the same seed
            k3 = jax.random.fold_in(key, 2)
            self.params["rank_head"] = init_head(
                k3, 2 * cfg.encoder.d_model, cfg.fc_hidden, cfg.n_fc_layers)
        self._n_traces = 0
        self.num_dispatches = 0
        #: log-space residual stats from ``fit`` (0, 0 = unknown spread)
        self.resid_mu = 0.0
        self.resid_sigma = 0.0
        #: per-iteration-step residual stats (Fig. 2(b): the error profile
        #: is step-dependent — fresh predictions are much noisier than deep
        #: ones, so upper quantiles hedge them harder): step -> (mu, sigma)
        self.resid_by_step: Dict[int, Tuple[float, float]] = {}
        self._apply = jax.jit(self._apply_fn)

    @property
    def num_traces(self) -> int:
        """XLA traces of the *current* jitted apply — the compile-count
        introspection hook.  Incremented by the Python side effect in
        ``_apply_fn`` (which runs only while JAX traces a new input shape)
        and reset whenever ``fit`` re-jits the apply, so for a predictor
        doing serving-path inference it stays <= the number of shape
        buckets no matter how the scheduling pool grows.  ``evaluate``
        drives its own chunked-but-bucketed shapes and adds their traces."""
        return self._n_traces

    # -------------------------------------------------------------- #
    def _apply_fn(self, params, tokens, mask):
        self._n_traces += 1  # Python side effect: runs once per trace
        cls, mean = E.encode(params["encoder"], self.cfg.encoder, tokens, mask)
        feats = jnp.concatenate([cls, mean], axis=-1)
        raw = apply_head(params["head"], feats)
        if self.cfg.predict_log:
            # wide clip: the gradient must not die at init (raw ≈ prior)
            out = jnp.exp(jnp.clip(raw, -2.0, 8.0))  # e^8 ≈ 3k > MAX_OUTPUT
        else:
            out = jnp.maximum(raw, 1.0)
        if self.cfg.ranking is None:
            return out
        # ranking head shares the trunk — same dispatch, no extra encoder
        # pass.  exp keeps the score a token-scale pseudo-length, so it
        # composes with the scheduler's banding/aging/debt arithmetic; only
        # its ORDER is trained (magnitudes stay the regression head's job)
        rank_raw = apply_head(params["rank_head"], feats)
        return out, jnp.exp(jnp.clip(rank_raw, -2.0, 8.0))

    def _run_tokens(self, token_lists: Sequence[Sequence[int]]):
        """Pad to the (batch, seq) bucket and run ONE jitted dispatch.

        Returns ``(raw_output, b)`` where ``raw_output`` is the jit result
        — a ``(means, rank_scores)`` tuple when the ranking head is enabled
        — and ``b`` the true batch size for slicing padding off."""
        ml = self.cfg.max_len
        b = len(token_lists)
        self.num_dispatches += 1
        longest = max(min(len(t), ml) for t in token_lists)
        bb = batch_bucket(b)
        sl = seq_bucket(longest, ml)
        toks = np.zeros((bb, sl), np.int32)
        mask = np.zeros((bb, sl), bool)
        for i, t in enumerate(token_lists):
            t = list(t)[:sl]
            toks[i, : len(t)] = t
            mask[i, : len(t)] = True
        return self._apply(self.params, toks, mask), b

    def predict_tokens(self, token_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """One batched inference dispatch, shape-bucketed.

        The batch dimension is padded to the next power of two and the
        sequence dimension to the ``seq_bucket`` ladder (capped at
        ``max_len``), so the jitted apply compiles once per (batch, seq)
        bucket instead of once per raw pool shape.  Padding rows are fully
        masked (the encoder's masked attention/pooling make them inert) and
        sliced off before returning."""
        if len(token_lists) == 0:
            return np.zeros((0,))
        out, b = self._run_tokens(token_lists)
        if self.cfg.ranking is not None:
            out = out[0]
        return np.asarray(out)[:b]

    def predict_tokens_ranked(
            self, token_lists: Sequence[Sequence[int]]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Both heads from the SAME single dispatch: (means, rank_scores)."""
        if self.cfg.ranking is None:
            raise ValueError(
                "ranking head disabled — construct the predictor with "
                "PredictorConfig(ranking=RankingConfig(...))")
        if len(token_lists) == 0:
            return np.zeros((0,)), np.zeros((0,))
        (m, r), b = self._run_tokens(token_lists)
        return np.asarray(m)[:b], np.asarray(r)[:b]

    # -------------------------------------------------------------- #
    def _job_input(self, job: Job) -> List[int]:
        from repro.data.dataset import clip_step_input

        return clip_step_input(job.prompt_tokens, job.generated,
                               self.cfg.max_len)

    def predict_jobs(self, jobs: Sequence[Job]) -> np.ndarray:
        """Batched point prediction for a whole pool (one encoder call)."""
        if not jobs:
            return np.zeros((0,))
        return self.predict_tokens([self._job_input(j) for j in jobs])

    def predict(self, jobs: Sequence[Job]) -> List[LengthPrediction]:
        jobs = list(jobs)
        if not jobs:
            return []
        if self.cfg.ranking is None:
            return super().predict(jobs)
        # two-head path: one fused dispatch fills both heads, and the
        # ranking score rides on the prediction next to the calibrated mean
        means, ranks = self.predict_tokens_ranked(
            [self._job_input(j) for j in jobs])
        return [replace(self._prediction(j, float(m)), rank_score=float(r))
                for j, m, r in zip(jobs, means, ranks)]

    def _prediction(self, job: Job, mean: float) -> LengthPrediction:
        if self.resid_sigma <= 0.0:
            return LengthPrediction(mean=mean)
        step = job.tokens_generated // WINDOW
        key = min(step, max(self.resid_by_step, default=0))
        mu, s = self.resid_by_step.get(key, (self.resid_mu, self.resid_sigma))
        ladder = _lognormal_ladder(mean, mu, s)
        std = mean * math.exp(mu + 0.5 * s * s) * math.sqrt(
            max(math.expm1(s * s), 0.0))
        return LengthPrediction(mean=mean, std=std, quantiles=ladder)

    # -------------------------------------------------------------- #
    def loss_fn(self, params, batch):
        out = self._apply_fn(params, batch["tokens"], batch["mask"])
        rank_pred = None
        if self.cfg.ranking is not None:
            pred, rank_pred = out
        else:
            pred = out
        target = batch["labels"]
        if self.cfg.predict_log:
            err = jnp.log(pred) - jnp.log(jnp.maximum(target, 1.0))
        else:
            err = (pred - target) / 100.0
        # Huber for robustness against the long tail
        huber = jnp.where(jnp.abs(err) < 1.0, 0.5 * err * err,
                          jnp.abs(err) - 0.5)
        mae = jnp.mean(jnp.abs(pred - target))
        total = jnp.mean(huber)
        metrics = {"mae": mae}
        if rank_pred is not None:
            # joint training: rank scores compared in log space (the exact
            # inverse of the head's exp within the clip window), pairs
            # restricted to valid (unpadded) rows
            valid = batch["mask"].any(axis=-1)
            rloss = ranking_loss(self.cfg.ranking, jnp.log(rank_pred),
                                 target, valid, steps=batch.get("steps"))
            total = total + self.cfg.ranking.weight * rloss
            metrics["rank_loss"] = rloss
        return total, metrics

    def fit(self, train_samples: List[StepSample], *, num_steps: int = 600,
            batch_size: int = 32, log_fn=None) -> Dict:
        from repro.data.dataset import batch_iterator

        mask = None
        if self.cfg.freeze_encoder:
            mask = {
                "encoder": jax.tree_util.tree_map(lambda _: False,
                                                  self.params["encoder"]),
                "head": jax.tree_util.tree_map(lambda _: True,
                                               self.params["head"]),
            }
            if "rank_head" in self.params:
                mask["rank_head"] = jax.tree_util.tree_map(
                    lambda _: True, self.params["rank_head"])
        it = batch_iterator(train_samples, batch_size, self.cfg.max_len)
        opt = AdamWConfig(lr=self.cfg.lr, warmup_steps=max(num_steps // 20, 1),
                          total_steps=num_steps, weight_decay=0.01)
        self.params, history = train(
            self.params, self.loss_fn, it, opt, num_steps=num_steps,
            trainable_mask=mask, log_every=max(num_steps // 10, 1),
            log_fn=log_fn,
        )
        self._apply = jax.jit(self._apply_fn)
        self._fit_residuals(train_samples)
        # fresh jit cache -> fresh compile count (training traced
        # _apply_fn under its own jit; those compiles are gone now, and the
        # residual-estimation chunks above drove their own shapes)
        self._n_traces = 0
        return history

    def _fit_residuals(self, samples: Sequence[StepSample],
                       cap: int = 512, min_per_step: int = 16) -> None:
        """Estimate the log-space residual distribution log(actual/pred) on
        (a slice of) the training samples — the quantile-ladder prior.

        Both pooled (``resid_mu``/``resid_sigma``) and per iteration step
        (``resid_by_step``, Fig. 2(b)): early-step predictions carry much
        wider residuals than deep ones, so a risk quantile built from the
        per-step spread hedges fresh, uncertain jobs harder than confident
        deep ones — which is what actually re-orders a pool."""
        sub = list(samples[:cap])
        if len(sub) < 8:
            return
        pred = self._predict_samples(sub)
        y = np.array([max(s.remaining, 1) for s in sub], np.float64)
        logr = np.log(y) - np.log(np.maximum(pred, 1e-6))
        self.resid_mu = float(np.mean(logr))
        self.resid_sigma = float(np.std(logr))
        self.resid_by_step = {}
        steps = np.array([s.step for s in sub])
        for k in sorted(set(int(s) for s in steps)):
            r = logr[steps == k]
            if len(r) >= min_per_step:
                self.resid_by_step[k] = (float(np.mean(r)),
                                         float(np.std(r)))

    def _predict_samples(self, samples: Sequence[StepSample],
                         chunk: int = 256, *,
                         want_rank: bool = False) -> np.ndarray:
        """Chunked, bucket-padded inference over pre-built StepSamples.

        Pads PER CHUNK (batch dimension to the power-of-two bucket, sequence
        to the configured ``max_len``) instead of materialising one giant
        padded array for the whole sample list — evaluating a large trace
        set stays O(chunk) memory and compiles at most one shape per batch
        bucket.  ``want_rank`` selects the ranking head's scores instead of
        the regression means (two-head predictors only)."""
        if want_rank and self.cfg.ranking is None:
            raise ValueError(
                "ranking head disabled — construct the predictor with "
                "PredictorConfig(ranking=RankingConfig(...))")
        ml = self.cfg.max_len
        preds = []
        for i in range(0, len(samples), chunk):
            part = samples[i: i + chunk]
            bb = batch_bucket(len(part))
            # same pad convention as training's pad_batch (PAD_ID, masked)
            toks = np.full((bb, ml), PAD_ID, np.int32)
            msk = np.zeros((bb, ml), bool)
            for r, s in enumerate(part):
                t = s.tokens[:ml]
                toks[r, : len(t)] = t
                msk[r, : len(t)] = True
            out = self._apply(self.params, toks, msk)
            if self.cfg.ranking is not None:
                out = out[1] if want_rank else out[0]
            preds.append(np.asarray(out)[: len(part)])
        return np.concatenate(preds) if preds else np.zeros((0,))

    # -------------------------------------------------------------- #
    def evaluate(self, samples: List[StepSample]) -> Dict[str, float]:
        """MAE / RMSE / R² — the paper's Table 2 metrics.

        Pads per 256-row chunk (see :meth:`_predict_samples`) rather than
        one ``pad_batch`` over the whole list: a 100k-sample trace set no
        longer materialises a (100k, max_len) array up front, and the
        chunked shapes stay on the batch-bucket ladder so traces are
        bounded."""
        if not samples:
            return {"mae": float("nan"), "rmse": float("nan"),
                    "r2": float("nan"), "kendall_tau": float("nan")}
        pred = self._predict_samples(samples)
        y = np.array([s.remaining for s in samples], np.float32)
        mae = float(np.mean(np.abs(pred - y)))
        rmse = float(np.sqrt(np.mean((pred - y) ** 2)))
        ss_res = float(np.sum((pred - y) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        r2 = 1.0 - ss_res / max(ss_tot, 1e-9)
        return {"mae": mae, "rmse": rmse, "r2": r2,
                "kendall_tau": kendall_tau(pred, y)}

    def evaluate_rank(self, samples: List[StepSample]) -> Dict[str, float]:
        """Kendall-τ of the pool ordering — the metric ISRTF actually needs.

        Scores come from the ranking head when enabled and from the
        regression mean otherwise, so single-head and two-head predictors
        are directly comparable at equal encoder budget."""
        if not samples:
            return {"kendall_tau": float("nan")}
        scores = self._predict_samples(
            samples, want_rank=self.cfg.ranking is not None)
        y = np.array([s.remaining for s in samples], np.float32)
        return {"kendall_tau": kendall_tau(scores, y)}

    def evaluate_per_step(self, samples: List[StepSample],
                          max_step: int = 6) -> Dict[int, float]:
        """MAE bucketed by iteration index — the paper's Fig. 2(b)."""
        out = {}
        for k in range(max_step):
            sub = [s for s in samples if s.step == k]
            if len(sub) >= 5:
                out[k] = self.evaluate(sub)["mae"]
        return out


# --------------------------------------------------------------------------- #
# Calibration wrappers (online feedback consumers)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class CalibrationConfig:
    """How a base predictor is wrapped for serving-time calibration."""

    #: EMA multiplicative debiasing (EMADebiasedPredictor)
    debias: bool = False
    #: EMA weight for the log-bias estimate
    ema_alpha: float = 0.1
    #: distribution-free quantiles from rolling residuals (ConformalPredictor)
    conformal: bool = False
    #: rolling residual-window size (per step bucket when ``by_step``)
    window: int = 256
    #: residuals required before a wrapper's estimate is trusted
    min_samples: int = 16
    #: Mondrian bucketing: estimate per iteration step (Fig. 2(b): the error
    #: profile is step-dependent), falling back to the pooled estimate while
    #: a step's bucket is cold
    by_step: bool = True
    #: steps >= this share one bucket
    max_step_bucket: int = 6

    @classmethod
    def from_name(cls, name: str, **kw) -> "CalibrationConfig":
        """``none | ema | conformal | ema+conformal`` -> config."""
        parts = {p for p in name.replace(" ", "").split("+") if p}
        known = {"none", "ema", "conformal"}
        if not parts or not parts <= known:
            raise ValueError(
                f"unknown calibration {name!r} (combine {sorted(known)})")
        return cls(debias="ema" in parts, conformal="conformal" in parts, **kw)


class CalibratedPredictor(LengthPredictor):
    """Base for calibration wrappers: composes over any base predictor,
    logs every prediction it hands out, and resolves those logs into
    residuals when :meth:`observe` reveals the ground truth.

    A logged entry is ``(tokens_generated_at_prediction, reference_mean)``;
    on an observation with ``actual_remaining`` known *now*, the actual
    remaining length at each logged point is
    ``(tokens_generated_now + actual_remaining) - tokens_at_prediction`` —
    exact both for mid-flight oracle feedback (simulation/replay) and for
    the finish-only feedback a live engine can provide.  CANCELLED/EXPIRED
    jobs are censored (they would have generated more); their logs are
    dropped without touching the estimate, so aborted requests never poison
    the residual window."""

    #: logged-but-unresolved predictions kept per job (oldest dropped)
    MAX_PENDING_PER_JOB = 64
    #: jobs tracked at once (serving cleans up via terminal observes; this
    #: bounds standalone/benchmark usage that never calls observe)
    MAX_PENDING_JOBS = 4096

    def __init__(self, base):
        self.base = base
        self._pending: "OrderedDict[int, List[Tuple[int, float]]]" = \
            OrderedDict()
        #: resolved residuals consumed so far
        self.n_observed = 0

    # -- step bucketing ------------------------------------------------- #
    def _bucket(self, tokens_generated: int) -> int:
        cfg = self.cfg
        if not cfg.by_step:
            return 0
        return min(tokens_generated // WINDOW, cfg.max_step_bucket)

    # -- prediction path ------------------------------------------------ #
    def predict(self, jobs: Sequence[Job]) -> List[LengthPrediction]:
        jobs = list(jobs)
        if not jobs:
            return []
        base_preds = self.base.predict(jobs)
        out = [self._adjust(j, p) for j, p in zip(jobs, base_preds)]
        for j, bp, ap in zip(jobs, base_preds, out):
            self._log(j, self._reference_mean(bp, ap))
        return out

    def _log(self, job: Job, ref_mean: float) -> None:
        entries = self._pending.setdefault(job.job_id, [])
        self._pending.move_to_end(job.job_id)
        entries.append((job.tokens_generated, ref_mean))
        if len(entries) > self.MAX_PENDING_PER_JOB:
            del entries[0]
        while len(self._pending) > self.MAX_PENDING_JOBS:
            self._pending.popitem(last=False)

    # -- feedback path --------------------------------------------------- #
    def observe(self, job: Job, actual_remaining: float) -> None:
        self.base.observe(job, actual_remaining)
        jid = job.job_id
        if job.state in (JobState.CANCELLED, JobState.EXPIRED):
            # censored: the request was aborted, its realised length says
            # nothing about what the model would have generated
            self._pending.pop(jid, None)
            return
        entries = self._pending.get(jid)
        if entries:
            total = job.tokens_generated + max(float(actual_remaining), 0.0)
            for g, ref in entries:
                actual = total - g
                if actual > 0.0 and ref > 0.0:
                    self._update(self._bucket(g), ref, actual)
                    self.n_observed += 1
            entries.clear()
        if job.state in TERMINAL_STATES:
            self._pending.pop(jid, None)

    # -- wrapper-specific hooks ------------------------------------------ #
    def _adjust(self, job: Job,
                pred: LengthPrediction) -> LengthPrediction:
        raise NotImplementedError

    def _reference_mean(self, base_pred: LengthPrediction,
                        adjusted: LengthPrediction) -> float:
        """Which mean the residual is measured against."""
        raise NotImplementedError

    def _update(self, bucket: int, predicted: float, actual: float) -> None:
        raise NotImplementedError


class EMADebiasedPredictor(CalibratedPredictor):
    """Multiplicative-bias correction from online feedback.

    Tracks ``log(predicted / actual)`` of the BASE predictor as an EMA —
    per iteration-step bucket when ``cfg.by_step`` (an undertrained
    regressor's bias is strongly step-dependent: early-step predictions
    regress to the corpus mean) — and divides the estimated bias back out
    of every prediction (mean, std, and quantile ladder all scale).  Under
    a constantly biased base (pred = b * truth) the correction converges to
    1/b, driving the served multiplicative bias to 1."""

    def __init__(self, base, cfg: Optional[CalibrationConfig] = None):
        super().__init__(base)
        self.cfg = cfg if cfg is not None else CalibrationConfig(debias=True)
        n = (self.cfg.max_step_bucket + 1) if self.cfg.by_step else 1
        self._log_bias = [0.0] * n
        self._counts = [0] * n

    def bias(self, bucket: int = 0) -> float:
        """Current multiplicative bias estimate (predicted/actual)."""
        return math.exp(self._log_bias[bucket])

    def _correction(self, bucket: int) -> float:
        if self._counts[bucket] >= self.cfg.min_samples:
            return math.exp(-self._log_bias[bucket])
        # cold bucket: fall back to the pooled estimate across warm buckets
        warm = [(c, lb) for c, lb in zip(self._counts, self._log_bias)
                if c >= self.cfg.min_samples]
        if warm:
            tot = sum(c for c, _ in warm)
            return math.exp(-sum(c * lb for c, lb in warm) / tot)
        return 1.0

    def _adjust(self, job: Job,
                pred: LengthPrediction) -> LengthPrediction:
        f = self._correction(self._bucket(job.tokens_generated))
        if f == 1.0:
            return pred
        # rank_score passes through untouched: it is a pool-relative
        # ordering, not a magnitude, so debiasing must not rescale it
        return LengthPrediction(
            mean=pred.mean * f, std=pred.std * f,
            quantiles=tuple((q, v * f) for q, v in pred.quantiles),
            rank_score=pred.rank_score,
        )

    def _reference_mean(self, base_pred: LengthPrediction,
                        adjusted: LengthPrediction) -> float:
        return base_pred.mean  # the bias being estimated is the base's

    def _update(self, bucket: int, predicted: float, actual: float) -> None:
        x = math.log(max(predicted, 1e-6) / max(actual, 1e-6))
        a = self.cfg.ema_alpha
        if self._counts[bucket] == 0:
            self._log_bias[bucket] = x
        else:
            self._log_bias[bucket] += a * (x - self._log_bias[bucket])
        self._counts[bucket] += 1


class ConformalPredictor(CalibratedPredictor):
    """Distribution-free quantiles from a rolling residual window.

    Keeps the last ``cfg.window`` multiplicative residuals
    ``actual / predicted`` (per step bucket when ``cfg.by_step`` — Mondrian
    conformal, better conditional coverage when the error profile is
    step-dependent) and replaces the base's quantile ladder with

        quantile(q) = mean * Q_q({actual_i / predicted_i})

    using the split-conformal finite-sample correction
    ``ceil((n+1) q) / n``: on exchangeable residuals the q-quantile upper
    bound covers the realised length with probability >= q.  The point
    estimate (``mean``) passes through untouched, so conformal wrapping
    changes nothing until a risk level is actually consumed."""

    def __init__(self, base, cfg: Optional[CalibrationConfig] = None):
        super().__init__(base)
        self.cfg = cfg if cfg is not None else CalibrationConfig(conformal=True)
        n = (self.cfg.max_step_bucket + 1) if self.cfg.by_step else 1
        self._scores: List[Deque[float]] = [deque(maxlen=self.cfg.window)
                                            for _ in range(n)]
        #: sorted-window memo: bucket -> (version-at-sort, sorted scores);
        #: sorting sits on the scheduling hot path (every scored job) and
        #: the window only changes when a residual lands, not per quantile
        self._version = 0
        self._sorted: Dict[int, Tuple[int, Optional[np.ndarray]]] = {}

    def _window(self, bucket: int) -> Optional[np.ndarray]:
        hit = self._sorted.get(bucket)
        if hit is not None and hit[0] == self._version:
            return hit[1]
        s = self._scores[bucket]
        if len(s) < self.cfg.min_samples:
            # cold bucket: pool every bucket's residuals
            pooled = [x for d in self._scores for x in d]
            out = (np.sort(np.asarray(pooled))
                   if len(pooled) >= self.cfg.min_samples else None)
        else:
            out = np.sort(np.asarray(s))
        self._sorted[bucket] = (self._version, out)
        return out

    @staticmethod
    def _rung(s: np.ndarray, q: float) -> float:
        n = len(s)
        k = min(int(math.ceil((n + 1) * q)), n)
        return float(s[k - 1])

    def ratio_quantile(self, q: float, bucket: int = 0) -> Optional[float]:
        """Finite-sample-corrected empirical quantile of the residual
        ratios, or None while the window is cold."""
        s = self._window(bucket)
        if s is None:
            return None
        return self._rung(s, q)

    def _adjust(self, job: Job,
                pred: LengthPrediction) -> LengthPrediction:
        bucket = self._bucket(job.tokens_generated)
        s = self._window(bucket)
        if s is None:
            return pred
        ladder = tuple((q, pred.mean * self._rung(s, q))
                       for q in QUANTILE_GRID)
        return LengthPrediction(mean=pred.mean, std=pred.std,
                                quantiles=ladder,
                                rank_score=pred.rank_score)

    def _reference_mean(self, base_pred: LengthPrediction,
                        adjusted: LengthPrediction) -> float:
        return adjusted.mean  # score the mean actually served (post-debias)

    def _update(self, bucket: int, predicted: float, actual: float) -> None:
        self._scores[bucket].append(actual / max(predicted, 1e-6))
        self._version += 1  # invalidate every memoised sorted window


# --------------------------------------------------------------------------- #
# RankedPredictor — serving adapter for the two-head model
# --------------------------------------------------------------------------- #


class RankedPredictor(LengthPredictor):
    """Serving-time learning-to-rank predictor over a two-head BGE model.

    ``predict(pool)`` delegates to the two-head :class:`BGEPredictor` (one
    fused dispatch fills both heads; every :class:`LengthPrediction`
    carries ``rank_score`` next to the calibrated ``mean``) and logs the
    inputs it scored.  ``observe()`` resolves those logs into ground-truth
    remaining lengths, keeps them in a rolling window, and every
    ``update_every`` resolved observations harvests ``pairs_per_update``
    record pairs — drawn WITHOUT replacement by a seeded RNG, so the pair
    sequence is a pure function of the observation order and the seed —
    into one fixed-shape SGD step on BOTH heads (encoder frozen online;
    the joint :meth:`BGEPredictor.loss_fn` supplies the regression Huber
    term and the pairwise/listwise ranking term).

    Censoring matches :class:`CalibratedPredictor`: CANCELLED/EXPIRED jobs
    have their logs dropped before any pair can form — an aborted
    request's realised length says nothing about what the model would have
    generated.  ``pair_log`` records the (job_id, job_id) pairs that
    entered training batches; the censoring/determinism tests read it.

    Composes under the calibration wrappers (``make_predictor("ranked",
    bge=..., calibration="ema+conformal")``): they adjust magnitudes, pass
    ``rank_score`` through untouched, and forward ``observe`` here first.
    """

    #: logged-but-unresolved prediction inputs kept per job (oldest dropped)
    MAX_PENDING_PER_JOB = 8
    #: jobs tracked at once (serving cleans up via terminal observes)
    MAX_PENDING_JOBS = 4096

    def __init__(self, base: "BGEPredictor", *, seed: int = 0,
                 window: int = 256, pairs_per_update: int = 8,
                 update_every: int = 32, online_lr: float = 1e-4):
        if not isinstance(base, BGEPredictor) or base.cfg.ranking is None:
            raise ValueError(
                "RankedPredictor needs a two-head BGEPredictor — construct "
                "it with PredictorConfig(ranking=RankingConfig(...))")
        self.base = base
        self._rng = np.random.RandomState(seed)
        self._pending: "OrderedDict[int, List[Tuple[int, Tuple[int, ...]]]]" \
            = OrderedDict()
        #: rolling window of resolved ground truth:
        #: (job_id, input_tokens, actual_remaining, step_at_prediction)
        self._records: Deque[Tuple[int, Tuple[int, ...], float, int]] = \
            deque(maxlen=window)
        self.pairs_per_update = pairs_per_update
        self.update_every = update_every
        self.online_lr = online_lr
        #: resolved ground-truth records consumed so far
        self.n_observed = 0
        #: harvested training pairs so far
        self.n_pairs = 0
        #: online SGD steps taken so far
        self.n_updates = 0
        #: (job_id_a, job_id_b) pairs that entered online training batches
        self.pair_log: List[Tuple[int, int]] = []
        self._since_update = 0
        self._grad = jax.jit(jax.grad(self._heads_loss))

    # -- prediction path ------------------------------------------------- #
    def predict(self, jobs: Sequence[Job]) -> List[LengthPrediction]:
        jobs = list(jobs)
        if not jobs:
            return []
        preds = self.base.predict(jobs)
        for j in jobs:
            entries = self._pending.setdefault(j.job_id, [])
            self._pending.move_to_end(j.job_id)
            entries.append((j.tokens_generated,
                            tuple(self.base._job_input(j))))
            if len(entries) > self.MAX_PENDING_PER_JOB:
                del entries[0]
            while len(self._pending) > self.MAX_PENDING_JOBS:
                self._pending.popitem(last=False)
        return preds

    # -- feedback path --------------------------------------------------- #
    def observe(self, job: Job, actual_remaining: float) -> None:
        jid = job.job_id
        if job.state in (JobState.CANCELLED, JobState.EXPIRED):
            # censored: drop the logs BEFORE any pair can form
            self._pending.pop(jid, None)
            return
        entries = self._pending.get(jid)
        if entries:
            total = job.tokens_generated + max(float(actual_remaining), 0.0)
            for g, toks in entries:
                actual = total - g
                if actual > 0.0:
                    self._records.append((jid, toks, actual, g // WINDOW))
                    self.n_observed += 1
                    self._since_update += 1
            entries.clear()
        if job.state in TERMINAL_STATES:
            self._pending.pop(jid, None)
        if self._since_update >= self.update_every:
            self._since_update = 0
            self._update_heads()

    # -- online head training -------------------------------------------- #
    def _heads_loss(self, heads, encoder, batch):
        loss, _ = self.base.loss_fn({"encoder": encoder, **heads}, batch)
        return loss

    def _update_heads(self) -> None:
        recs = list(self._records)
        n = 2 * self.pairs_per_update
        if len(recs) < n:
            return
        idx = self._rng.choice(len(recs), size=n, replace=False)
        rows = [recs[int(i)] for i in idx]
        self.pair_log.extend((rows[2 * t][0], rows[2 * t + 1][0])
                             for t in range(self.pairs_per_update))
        self.n_pairs += self.pairs_per_update
        # fixed (n, max_len) batch shape -> the grad step compiles ONCE
        ml = self.base.cfg.max_len
        toks = np.full((n, ml), PAD_ID, np.int32)
        msk = np.zeros((n, ml), bool)
        labels = np.zeros((n,), np.float32)
        steps = np.zeros((n,), np.int32)
        for r, (jid, t, actual, step) in enumerate(rows):
            t = list(t)[:ml]
            toks[r, : len(t)] = t
            msk[r, : len(t)] = True
            labels[r] = actual
            steps[r] = step
        batch = {"tokens": toks, "mask": msk, "labels": labels,
                 "steps": steps}
        heads = {k: v for k, v in self.base.params.items() if k != "encoder"}
        grads = self._grad(heads, self.base.params["encoder"], batch)
        lr = self.online_lr
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, heads, grads)
        # fresh dict (no in-place mutation): callers may hold the previous
        # params tree as a snapshot for benchmark isolation
        self.base.params = {"encoder": self.base.params["encoder"], **new}
        self.n_updates += 1


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #


def _make_oracle(seed: int, bias: float, bge):
    return OraclePredictor()


def _make_noisy(seed: int, bias: float, bge):
    return NoisyOraclePredictor(seed=seed, bias=bias)


def _make_bge(seed: int, bias: float, bge):
    if bge is None:
        raise ValueError("pass a trained BGEPredictor via bge=")
    return bge


def _make_ranked(seed: int, bias: float, bge):
    if bge is None:
        raise ValueError(
            "pass a trained two-head BGEPredictor via bge= "
            "(PredictorConfig(ranking=RankingConfig(...)))")
    if isinstance(bge, RankedPredictor):
        return bge
    return RankedPredictor(bge, seed=seed)


#: base-predictor registry: name -> factory(seed, bias, bge)
BASE_PREDICTORS = {
    "oracle": _make_oracle,
    "noisy_oracle": _make_noisy,
    "bge": _make_bge,
    "ranked": _make_ranked,
}


def wrap_calibration(base, calibration: Union[None, str, CalibrationConfig]):
    """Compose calibration wrappers over ``base``: EMA debias innermost
    (fixes the point estimate), conformal outermost (its residual window
    then scores the debiased mean it actually serves)."""
    if calibration is None:
        return base
    if isinstance(calibration, str):
        calibration = CalibrationConfig.from_name(calibration)
    pred = base
    if calibration.debias:
        pred = EMADebiasedPredictor(pred, calibration)
    if calibration.conformal:
        pred = ConformalPredictor(pred, calibration)
    return pred


def make_predictor(kind: str = "noisy_oracle", *, seed: int = 0, bge=None,
                   calibration: Union[None, str, CalibrationConfig] = None,
                   bias: float = 1.0):
    """Build a (possibly calibrated) predictor from the registry.

    ``kind`` selects the base (``oracle | noisy_oracle | bge | none``);
    ``calibration`` is a :class:`CalibrationConfig`, a name like
    ``"ema+conformal"``, or None; ``bias`` injects a synthetic
    multiplicative mis-calibration into the noisy oracle (benchmarks)."""
    if kind == "none":
        return None
    try:
        factory = BASE_PREDICTORS[kind]
    except KeyError:
        raise ValueError(
            f"unknown predictor {kind!r} "
            f"(have {sorted(BASE_PREDICTORS)} + 'none')") from None
    return wrap_calibration(factory(seed, bias, bge), calibration)
