"""JAX inference engine — the vLLM analogue the ELIS backend workers drive.

TPU-idiomatic design (see DESIGN.md §3): instead of paged KV blocks, a
fixed-capacity **slot-based** cache — every decode slot owns a contiguous
KV/state region of a statically-shaped batched cache, and slots advance
independently (per-slot ``len`` vector).  Slot recycling replaces page
allocation; preemption = slot eviction + recompute-on-resume.

The two features the paper adds to vLLM are first-class here:
  * **iteration-wise execution** — ``run_window`` executes exactly K tokens
    (or to EOS) for the scheduled batch and returns partial outputs;
  * **configurable priorities** — the scheduler decides which jobs hold
    slots each window; ``evict``/``add`` implement priority preemption.

Prefill padding: attention families right-pad prompts to a bucket length
(causality + the kv_len mask make pads harmless); SSM/hybrid families use
exact-length prefill because recurrent state would absorb pad positions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontend import Backend, ExecResult
from repro.core.job import Job
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.engine.sampler import SamplerConfig, sample
from repro.models import transformer as T


@dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 512
    max_output: int = 1024
    eos_id: int = EOS_ID
    prefill_bucket: int = 16
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    attn_impl: str = "xla"
    #: honour each request's own token budget (job.true_output_len acts as
    #: the request's ``max_tokens``, like vLLM's per-request cap)
    respect_job_max: bool = False


def _slot_update(big, small, slot: int):
    """Write a batch-1 cache pytree into slot ``slot`` of the batched cache."""

    def upd(b, s):
        if b.ndim == 1:  # per-slot "len" vector
            return b.at[slot].set(s[0])
        return b.at[:, slot].set(s[:, 0])

    return jax.tree_util.tree_map(upd, big, small)


class InferenceEngine:
    """One backend worker's execution engine (one model, N slots)."""

    def __init__(self, model_cfg, params, cfg: Optional[EngineConfig] = None):
        if cfg is None:
            cfg = EngineConfig()
        self.model_cfg = model_cfg
        self.params = params
        self.cfg = cfg
        self.cache = T.init_cache(model_cfg, cfg.max_slots, cfg.max_len)
        self.slot_job: List[Optional[int]] = [None] * cfg.max_slots
        self.slot_of: Dict[int, int] = {}
        self.last_token = np.full((cfg.max_slots, 1), PAD_ID, np.int32)
        self._key = jax.random.PRNGKey(0)

        mc, ec = model_cfg, cfg

        @jax.jit
        def _prefill(params, tokens, cache1, last_index):
            batch = {"tokens": tokens}
            return T.prefill(params, mc, batch, cache1,
                             attn_impl=ec.attn_impl, last_index=last_index)

        self._prefill = _prefill
        self._window_cache: Dict[int, object] = {}
        #: first generated token (sampled from prefill logits), pending emission
        self._pending_first: Dict[int, int] = {}

    # ------------------------------------------------------------------ #
    def _decode_window(self, window: int):
        """jit per window length (window is static for lax.scan)."""
        if window not in self._window_cache:
            mc, ec = self.model_cfg, self.cfg

            @jax.jit
            def fn(params, cache, last_tokens, key):
                def step(carry, _):
                    cache, toks, key = carry
                    logits, cache = T.decode_step(params, mc, toks, cache,
                                                  attn_impl=ec.attn_impl)
                    key, sub = jax.random.split(key)
                    nxt = sample(logits[:, -1, :], sub, ec.sampler)[:, None]
                    return (cache, nxt, key), nxt[:, 0]

                (cache, _, _), toks = jax.lax.scan(
                    step, (cache, last_tokens, key), None, length=window
                )
                return cache, jnp.swapaxes(toks, 0, 1)

            self._window_cache[window] = fn
        return self._window_cache[window]

    # ------------------------------------------------------------------ #
    def free_slots(self) -> int:
        return self.slot_job.count(None)

    def has_job(self, job_id: int) -> bool:
        return job_id in self.slot_of

    def add_job(self, job: Job) -> int:
        """Prefill into a free slot.

        Fresh job: consume the prompt; *sample the first output token from
        the prefill logits* (emitted by the next ``run_window``).
        Resumed job (preempted earlier): recompute KV for
        ``prompt + generated[:-1]`` and seed decode with the last already-
        emitted token — nothing is double-emitted.
        """
        slot = self.slot_job.index(None)
        if job.generated:
            tokens = list(job.prompt_tokens) + list(job.generated)[:-1]
        else:
            tokens = list(job.prompt_tokens)
        true_len = len(tokens)
        if self.model_cfg.family in ("ssm", "hybrid"):
            padded = tokens  # exact length (recurrent state must stay clean)
        else:
            bucket = -(-true_len // self.cfg.prefill_bucket) * self.cfg.prefill_bucket
            padded = tokens + [PAD_ID] * (bucket - true_len)
        arr = jnp.asarray([padded], jnp.int32)
        cache1 = T.init_cache(self.model_cfg, 1, self.cfg.max_len)
        logits, cache1 = self._prefill(self.params, arr, cache1,
                                       jnp.asarray([true_len - 1]))
        cache1["len"] = jnp.asarray([true_len], jnp.int32)
        self.cache = _slot_update(self.cache, cache1, slot)
        self.slot_job[slot] = job.job_id
        self.slot_of[job.job_id] = slot
        if job.generated:
            self.last_token[slot, 0] = job.generated[-1]
        else:
            first = int(np.argmax(np.asarray(logits)[0, -1]))
            self._pending_first[job.job_id] = first
            self.last_token[slot, 0] = first
        return slot

    def evict_job(self, job_id: int) -> None:
        slot = self.slot_of.pop(job_id, None)
        self._pending_first.pop(job_id, None)
        if slot is not None:
            self.slot_job[slot] = None
            self.last_token[slot, 0] = PAD_ID

    # ------------------------------------------------------------------ #
    def run_window(self, jobs: Sequence[Job], window: int) -> Tuple[List[List[int]], List[bool]]:
        """Execute K decode steps for ``jobs`` (all must hold slots).
        Returns (new_tokens_per_job, finished_per_job)."""
        for job in jobs:
            if not self.has_job(job.job_id):
                self.add_job(job)
        fn = self._decode_window(window)
        self._key, sub = jax.random.split(self._key)
        self.cache, toks = fn(self.params, self.cache,
                              jnp.asarray(self.last_token), sub)
        toks = np.asarray(toks)  # (slots, K)
        out_tokens: List[List[int]] = []
        finished: List[bool] = []
        lens = np.asarray(self.cache["len"]).copy()
        for job in jobs:
            slot = self.slot_of[job.job_id]
            scanned = toks[slot].tolist()
            pending = self._pending_first.pop(job.job_id, None)
            if pending is not None:
                # first emission comes from the prefill logits; the scan's
                # K-th token is unconsumed (roll its cache write back)
                seq = [pending] + scanned[: window - 1]
                consumed_scanned = len(seq) - 1
            else:
                seq = scanned[:window]
                consumed_scanned = len(seq)
            cap = self.cfg.max_output
            if self.cfg.respect_job_max and job.true_output_len > 0:
                cap = min(cap, job.true_output_len)
            if self.cfg.eos_id in seq:
                cut = seq.index(self.cfg.eos_id) + 1
                dropped = len(seq) - cut
                seq = seq[:cut]
                consumed_scanned -= dropped
                fin = True
            else:
                fin = False
            room = cap - job.tokens_generated
            if len(seq) >= room:
                dropped = len(seq) - room
                seq = seq[:room]
                consumed_scanned -= dropped
                fin = True
            out_tokens.append(seq)
            finished.append(fin)
            self.last_token[slot, 0] = seq[-1] if seq else PAD_ID
            # roll back the cache pointer past unconsumed scan writes
            lens[slot] -= window - consumed_scanned
        self.cache["len"] = jnp.asarray(lens)
        return out_tokens, finished


# --------------------------------------------------------------------------- #
# Backend adapter for the ELIS frontend
# --------------------------------------------------------------------------- #


class EngineExecutor(Backend):
    """Wraps per-node InferenceEngines behind the frontend Backend ABC.
    Durations are measured wall-clock — the live-system evaluation mode."""

    def __init__(self, engines: Dict[int, InferenceEngine]):
        self.engines = engines

    def capacity(self, node: int) -> int:
        return self.engines[node].cfg.max_slots

    def free_capacity(self, node: int) -> int:
        return self.engines[node].free_slots()

    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float) -> ExecResult:
        eng = self.engines[node]
        t0 = time.perf_counter()
        # capacity: evict nothing here — the frontend already chose the batch;
        # engine must have slots for every scheduled job
        needed = sum(1 for job in jobs if not eng.has_job(job.job_id))
        if needed > eng.free_slots():
            raise RuntimeError(
                f"node {node}: batch needs {needed} free slots, "
                f"engine has {eng.free_slots()}"
            )
        tokens, finished = eng.run_window(jobs, window)
        dur = time.perf_counter() - t0
        return ExecResult(dur, tokens, finished)

    def evict(self, node: int, job: Job) -> None:
        self.engines[node].evict_job(job.job_id)
