"""Paper Table 2: pre-trained (untrained head) vs fine-tuned BGE predictor.

Paper numbers (LMSYS dataset): pretrained MAE 175.99 / RMSE 224.98 / R² -1.58;
fine-tuned MAE 71.48 / RMSE 101.29 / R² 0.48; on the vLLM-collected set the
final model reaches MAE 19.9 / RMSE 34.3 / R² 0.852 (§4.2).

Our claim to reproduce: fine-tuning moves R² from ≲0 to strongly positive and
slashes MAE/RMSE on the synthetic LMSYS-like workload.

Every predictor row additionally reports **Kendall-τ** — ISRTF consumes only
the *order* of predicted remaining lengths, so rank correlation is the metric
the scheduler actually cares about — and a jointly trained two-head model
(regression + learning-to-rank head at the same encoder budget, see
``repro.models.objective.RankingConfig``) reports both heads' τ side by side.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import BGEPredictor, PredictorConfig, RankingConfig
from repro.data import make_predictor_dataset
from repro.models.encoder import EncoderArchConfig

from benchmarks.common import save_results


def run(quick: bool = False) -> List[Dict]:
    n_req = 600 if quick else 2000
    steps = 300 if quick else 1200
    # paper trains the full BGE at lr 1e-4; our scratch-substitute encoder
    # (DESIGN.md §7) trains from random init, so a proportionally higher LR
    cfg = PredictorConfig(
        encoder=EncoderArchConfig(d_model=128, n_heads=4, n_layers=3,
                                  d_ff=256, max_len=192),
        n_fc_layers=8, fc_hidden=256, max_len=192, lr=3e-4,
    )
    tr, va, te = make_predictor_dataset(n_req, seed=0, max_len=192,
                                        max_steps=6)
    pred = BGEPredictor(cfg, seed=0)
    before = pred.evaluate(te)
    t0 = time.time()
    pred.fit(tr, num_steps=steps, batch_size=32)
    train_s = time.time() - t0
    after = pred.evaluate(te)
    # two-head model at the SAME encoder budget / schedule: the ranking
    # head is judged purely on ordering (Kendall-τ of its pool ranking)
    two = BGEPredictor(
        PredictorConfig(
            encoder=cfg.encoder, n_fc_layers=cfg.n_fc_layers,
            fc_hidden=cfg.fc_hidden, max_len=cfg.max_len, lr=cfg.lr,
            ranking=RankingConfig()),
        seed=0)
    t0 = time.time()
    two.fit(tr, num_steps=steps, batch_size=32)
    two_train_s = time.time() - t0
    two_reg = two.evaluate(te)
    two_rank_tau = two.evaluate_rank(te)["kendall_tau"]
    rows = [
        {"model": "untrained (≈ pre-trained BGE)", **before},
        {"model": "fine-tuned", **after,
         "train_seconds": round(train_s, 1), "train_steps": steps,
         "n_train_samples": len(tr), "n_test_samples": len(te)},
        {"model": "fine-tuned two-head (regression head)", **two_reg,
         "train_seconds": round(two_train_s, 1), "train_steps": steps},
        {"model": "fine-tuned two-head (rank head)",
         "kendall_tau": two_rank_tau},
        {"model": "paper pretrained (LMSYS)", "mae": 175.99, "rmse": 224.98,
         "r2": -1.58},
        {"model": "paper fine-tuned (LMSYS)", "mae": 71.48, "rmse": 101.29,
         "r2": 0.48},
        {"model": "paper fine-tuned (vLLM set)", "mae": 19.92, "rmse": 34.33,
         "r2": 0.852},
    ]
    save_results("table2_predictor", rows)
    return rows


#: the trained predictor is reused by fig2 — cache it at module scope
_cache = {}


def trained_predictor(quick: bool = False):
    key = ("pred", quick)
    if key not in _cache:
        n_req = 600 if quick else 2000
        steps = 300 if quick else 1200
        cfg = PredictorConfig(
            encoder=EncoderArchConfig(d_model=128, n_heads=4, n_layers=3,
                                      d_ff=256, max_len=192),
            n_fc_layers=8, fc_hidden=256, max_len=192, lr=3e-4,
        )
        tr, va, te = make_predictor_dataset(n_req, seed=0, max_len=192,
                                            max_steps=6)
        pred = BGEPredictor(cfg, seed=0)
        pred.fit(tr, num_steps=steps, batch_size=32)
        _cache[key] = (pred, te)
    return _cache[key]


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
