"""JCT / queuing-delay / throughput metrics (paper §6 evaluation).

Two aggregation paths share one metric surface:

* :func:`summarize` — exact, collect-then-percentile over a list of
  finished Job/Response records (all percentile families computed in a
  single fused ``np.percentile`` call, one sort);
* :class:`StreamingSummary` — constant-memory streaming aggregation for
  million-request runs: exact counts/sums/extremes plus
  :class:`QuantileSketch`-backed percentiles (log-bucketed histogram,
  relative error ≤ ``QuantileSketch.rel_error`` ≈ 0.3% at the defaults).
  Mergeable across shards/tenants.  Used by ``repro.simulate.scale`` and
  the large benches (`multi_node`, `predictor_calibration`, `sim_scale`).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.job import Job, JobState


def prediction_stats(job: Job) -> Tuple[Optional[float], Optional[float]]:
    """Per-request prediction-error stats from the job's scored trace.

    Returns ``(mae, bias)`` over every ``(tokens_at, expected_remaining)``
    entry the scheduler recorded (``Job.pred_trace``), measured against the
    realised remaining length at that point — only computable once the job
    FINISHED (an aborted job's realised length is censored).  ``bias`` is
    the geometric mean of predicted/actual (1.0 = perfectly calibrated,
    < 1 = underestimates)."""
    if job.state is not JobState.FINISHED or not job.pred_trace:
        return None, None
    total = job.tokens_generated
    errs, logr = [], []
    for g, m in job.pred_trace:
        actual = total - g
        # skip degenerate entries on EITHER side: SJF records a floored
        # 0.0 estimate once a job overruns its arrival prediction, and a
        # log-ratio against that (~ -19) would collapse the request's
        # geometric-mean bias to ~0 instead of reflecting the predictor
        if actual <= 0 or m <= 0:
            continue
        errs.append(abs(m - actual))
        logr.append(np.log(m / actual))
    if not errs:
        return None, None
    return float(np.mean(errs)), float(np.exp(np.mean(logr)))


def summarize(jobs: Sequence[Job]) -> Dict[str, float]:
    """Aggregate JCT/queuing/throughput metrics over finished jobs (or
    Response records — anything with the same timing surface)."""
    if not jobs:
        # zero requests finished (all cancelled/expired): report an empty
        # but well-formed summary rather than crashing the caller
        keys = ("jct_mean", "jct_p50", "jct_p99", "jct_min", "jct_max",
                "queuing_delay_mean", "throughput_rps", "makespan",
                "ttft_mean")
        out: Dict[str, float] = {k: 0.0 for k in keys}
        out["n"] = 0
        out["preemptions"] = 0
        return out
    jcts = np.array([j.jct() for j in jobs])
    qd = np.array([j.queuing_delay for j in jobs])
    makespan = max(j.finish_time for j in jobs) - min(
        j.arrival_time for j in jobs
    )
    # every percentile family in ONE fused call — a single sort of the JCT
    # array instead of one re-sort per metric (p0/p100 are exactly min/max)
    jct_min, jct_p50, jct_p99, jct_max = np.percentile(
        jcts, (0.0, 50.0, 99.0, 100.0))
    out = {
        "n": len(jobs),
        "jct_mean": float(jcts.mean()),
        "jct_p50": float(jct_p50),
        "jct_p99": float(jct_p99),
        "jct_min": float(jct_min),
        "jct_max": float(jct_max),
        "queuing_delay_mean": float(qd.mean()),
        "throughput_rps": len(jobs) / max(makespan, 1e-9),
        "makespan": float(makespan),
        "preemptions": int(sum(j.n_preemptions for j in jobs)),
        "ttft_mean": float(
            np.mean([
                j.first_token_time - j.arrival_time
                for j in jobs if j.first_token_time is not None
            ])
        ),
    }
    # prediction-error aggregates: present only when the records carry
    # per-request stats (Response.pred_mae / pred_bias from a
    # length-predicting policy) — raw Job summaries are unchanged
    maes = [v for j in jobs if (v := getattr(j, "pred_mae", None)) is not None]
    biases = [v for j in jobs
              if (v := getattr(j, "pred_bias", None)) is not None]
    if maes:
        out["pred_mae_mean"] = float(np.mean(maes))
    if biases:
        # geometric mean composes multiplicative per-request biases
        out["pred_bias_gmean"] = float(np.exp(np.mean(np.log(biases))))
    return out


def improvement(base: Dict[str, float], new: Dict[str, float],
                key: str = "jct_mean") -> float:
    """Percent reduction of ``key`` relative to ``base`` (paper Fig. 6)."""
    return 100.0 * (base[key] - new[key]) / base[key]


def kendall_tau(x: Sequence[float], y: Sequence[float]) -> float:
    """Kendall's τ-b rank correlation between ``x`` and ``y``.

    The metric the ranking head is actually judged on: ISRTF consumes only
    the *order* of predicted remaining lengths, and τ measures exactly how
    well that order matches the realised one (+1 = identical ordering,
    −1 = reversed, 0 = uncorrelated).  τ-b applies the tie correction
    ``(P − Q) / sqrt((P + Q + Tx)(P + Q + Ty))`` so heavily quantised
    predictions aren't rewarded for abstaining.

    O(n²) pairwise comparison, vectorised per row — fine at benchmark
    sample counts (≲ 10k); returns 0.0 when fewer than two samples or
    either argument is constant."""
    xa = np.asarray(x, np.float64)
    ya = np.asarray(y, np.float64)
    n = len(xa)
    if n != len(ya):
        raise ValueError(f"length mismatch: {n} vs {len(ya)}")
    if n < 2:
        return 0.0
    conc = disc = tx = ty = 0
    for i in range(n - 1):
        dx = xa[i + 1:] - xa[i]
        dy = ya[i + 1:] - ya[i]
        s = np.sign(dx) * np.sign(dy)
        conc += int(np.sum(s > 0))
        disc += int(np.sum(s < 0))
        tx += int(np.sum((dx == 0) & (dy != 0)))
        ty += int(np.sum((dy == 0) & (dx != 0)))
    denom = math.sqrt((conc + disc + tx) * (conc + disc + ty))
    if denom == 0.0:
        return 0.0
    return (conc - disc) / denom


# --------------------------------------------------------------------------- #
# Streaming aggregation (million-request runs: no stored Response lists)
# --------------------------------------------------------------------------- #


class QuantileSketch:
    """Streaming quantile sketch over positive values (log-bucketed
    histogram).

    Fixed geometric bins over ``[lo, hi)`` — a value maps to the bin holding
    its logarithm, so any quantile is reported with *relative* error at most
    half a bin width (:attr:`rel_error`, ≈ 0.3% at the defaults), using
    O(n_bins) memory regardless of how many values are added.  Values
    outside the range clamp into under/overflow bins and are reported as the
    observed min/max.  Sketches with identical bin layouts merge exactly
    (shard/tenant roll-ups)."""

    __slots__ = ("lo", "hi", "n_bins", "_log_lo", "_w", "counts",
                 "n", "total", "min", "max")

    def __init__(self, lo: float = 1e-4, hi: float = 1e6,
                 n_bins: int = 4096):
        assert 0 < lo < hi and n_bins > 0
        self.lo, self.hi, self.n_bins = float(lo), float(hi), int(n_bins)
        self._log_lo = math.log(lo)
        self._w = (math.log(hi) - self._log_lo) / n_bins
        # [0] = underflow, [1..n_bins] = geometric bins, [-1] = overflow
        self.counts = np.zeros(n_bins + 2, dtype=np.int64)
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def rel_error(self) -> float:
        """Worst-case relative quantile error for in-range values."""
        return math.exp(self._w / 2.0) - 1.0

    def add(self, values) -> None:
        x = np.asarray(values, dtype=np.float64).ravel()
        if x.size == 0:
            return
        self.n += int(x.size)
        self.total += float(x.sum())
        self.min = min(self.min, float(x.min()))
        self.max = max(self.max, float(x.max()))
        idx = np.floor(
            (np.log(np.maximum(x, 1e-300)) - self._log_lo) / self._w
        ).astype(np.int64) + 1
        np.clip(idx, 0, self.n_bins + 1, out=idx)
        self.counts += np.bincount(idx, minlength=self.counts.size)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def quantile(self, q: float) -> float:
        """The q-quantile (q in [0, 1]), nearest-rank over the histogram;
        in-range values are exact to within :attr:`rel_error`."""
        if self.n == 0:
            return 0.0
        rank = q * (self.n - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank, side="right"))
        if b <= 0 and self.counts[0] > 0:
            return self.min
        if b >= self.n_bins + 1:
            return self.max
        # geometric midpoint of the bin, clamped to the observed range
        mid = math.exp(self._log_lo + (b - 0.5) * self._w)
        return min(max(mid, self.min), self.max)

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        assert (self.lo, self.hi, self.n_bins) == \
               (other.lo, other.hi, other.n_bins), "incompatible bin layout"
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self


class StreamingSummary:
    """Constant-memory replacement for :func:`summarize`.

    Feed finished records one at a time (:meth:`add_response`) or as
    vectors (:meth:`add_batch`); :meth:`summarize` returns the same key
    surface as :func:`summarize` — means/counts/extremes exact, p50/p99
    from a :class:`QuantileSketch` (documented tolerance
    :attr:`QuantileSketch.rel_error`).  With ``slo_target`` set (seconds of
    JCT), also reports ``slo_attainment`` — the fraction of finished
    requests meeting the target."""

    def __init__(self, slo_target: Optional[float] = None):
        self.slo_target = slo_target
        self.sketch = QuantileSketch()
        self.n = 0
        self.qd_sum = 0.0
        self.ttft_sum = 0.0
        self.ttft_n = 0
        self.preemptions = 0
        self.slo_hits = 0
        self.arr_min = math.inf
        self.fin_max = -math.inf
        self.pred_mae_sum = 0.0
        self.pred_mae_n = 0
        self.pred_logbias_sum = 0.0
        self.pred_bias_n = 0

    # ------------------------------------------------------------------ #
    def add(self, jct: float, queuing_delay: float = 0.0, *,
            arrival: float = 0.0, ttft: Optional[float] = None,
            n_preemptions: int = 0, pred_mae: Optional[float] = None,
            pred_bias: Optional[float] = None) -> None:
        self.sketch.add(jct)
        self.n += 1
        self.qd_sum += queuing_delay
        self.preemptions += n_preemptions
        self.arr_min = min(self.arr_min, arrival)
        self.fin_max = max(self.fin_max, arrival + jct)
        if ttft is not None:
            self.ttft_sum += ttft
            self.ttft_n += 1
        if self.slo_target is not None and jct <= self.slo_target:
            self.slo_hits += 1
        if pred_mae is not None:
            self.pred_mae_sum += pred_mae
            self.pred_mae_n += 1
        if pred_bias is not None and pred_bias > 0:
            self.pred_logbias_sum += math.log(pred_bias)
            self.pred_bias_n += 1

    def add_response(self, r) -> None:
        """Add one finished Job/Response record (``summarize`` duck
        surface)."""
        jct = r.jct()
        ttft = (r.first_token_time - r.arrival_time
                if r.first_token_time is not None else None)
        self.add(jct, r.queuing_delay, arrival=r.arrival_time, ttft=ttft,
                 n_preemptions=r.n_preemptions,
                 pred_mae=getattr(r, "pred_mae", None),
                 pred_bias=getattr(r, "pred_bias", None))

    def add_batch(self, jct, queuing_delay, arrival, ttft,
                  n_preemptions) -> None:
        """Vectorized ingestion (the scale simulator's flush path).  All
        arguments are equal-length arrays; ``ttft`` entries may be NaN."""
        jct = np.asarray(jct, dtype=np.float64)
        if jct.size == 0:
            return
        arrival = np.asarray(arrival, dtype=np.float64)
        self.sketch.add(jct)
        self.n += int(jct.size)
        self.qd_sum += float(np.sum(queuing_delay))
        self.preemptions += int(np.sum(n_preemptions))
        self.arr_min = min(self.arr_min, float(arrival.min()))
        self.fin_max = max(self.fin_max, float((arrival + jct).max()))
        t = np.asarray(ttft, dtype=np.float64)
        ok = ~np.isnan(t)
        self.ttft_sum += float(t[ok].sum())
        self.ttft_n += int(ok.sum())
        if self.slo_target is not None:
            self.slo_hits += int(np.sum(jct <= self.slo_target))

    def merge(self, other: "StreamingSummary") -> "StreamingSummary":
        """Fold ``other`` in (tenant -> global roll-ups).  ``slo_hits``
        merges raw; ``slo_attainment`` is only reported when *this*
        summary has a target of its own."""
        self.sketch.merge(other.sketch)
        self.n += other.n
        self.qd_sum += other.qd_sum
        self.ttft_sum += other.ttft_sum
        self.ttft_n += other.ttft_n
        self.preemptions += other.preemptions
        self.slo_hits += other.slo_hits
        self.arr_min = min(self.arr_min, other.arr_min)
        self.fin_max = max(self.fin_max, other.fin_max)
        self.pred_mae_sum += other.pred_mae_sum
        self.pred_mae_n += other.pred_mae_n
        self.pred_logbias_sum += other.pred_logbias_sum
        self.pred_bias_n += other.pred_bias_n
        return self

    # ------------------------------------------------------------------ #
    def summarize(self) -> Dict[str, float]:
        if self.n == 0:
            keys = ("jct_mean", "jct_p50", "jct_p99", "jct_min", "jct_max",
                    "queuing_delay_mean", "throughput_rps", "makespan",
                    "ttft_mean")
            out: Dict[str, float] = {k: 0.0 for k in keys}
            out["n"] = 0
            out["preemptions"] = 0
            if self.slo_target is not None:
                out["slo_attainment"] = 0.0
            return out
        makespan = self.fin_max - self.arr_min
        out = {
            "n": self.n,
            "jct_mean": self.sketch.mean,
            "jct_p50": self.sketch.quantile(0.50),
            "jct_p99": self.sketch.quantile(0.99),
            "jct_min": self.sketch.min,
            "jct_max": self.sketch.max,
            "queuing_delay_mean": self.qd_sum / self.n,
            "throughput_rps": self.n / max(makespan, 1e-9),
            "makespan": float(makespan),
            "preemptions": int(self.preemptions),
            "ttft_mean": (self.ttft_sum / self.ttft_n
                          if self.ttft_n else 0.0),
        }
        if self.slo_target is not None:
            out["slo_attainment"] = self.slo_hits / self.n
        if self.pred_mae_n:
            out["pred_mae_mean"] = self.pred_mae_sum / self.pred_mae_n
        if self.pred_bias_n:
            out["pred_bias_gmean"] = math.exp(
                self.pred_logbias_sum / self.pred_bias_n)
        return out


def fairness_ratio(values: Dict[str, float]) -> float:
    """Max/min ratio across per-tenant metric values (1.0 = perfectly
    fair); 0.0 when fewer than two tenants have data.  A tenant sitting
    at exactly 0 (a degenerate zero mean JCT — e.g. every request
    finished within clock resolution) alongside a non-zero tenant is
    maximal unfairness by this ratio: reported as ``inf`` rather than
    tripping a ZeroDivisionError."""
    vals = [v for v in values.values() if v >= 0]
    if len(vals) < 2:
        return 0.0
    lo, hi = min(vals), max(vals)
    if lo == 0.0:
        return float("inf") if hi > 0.0 else 0.0
    return hi / lo


def summarize_by_tenant(jobs: Sequence, slo_targets: Optional[Dict[str, float]]
                        = None) -> Dict[str, Dict[str, float]]:
    """Exact per-tenant :func:`summarize` over finished records carrying a
    ``tenant`` attribute, plus ``slo_attainment`` for tenants with a target
    (fraction of finished requests with JCT ≤ target)."""
    slo_targets = slo_targets or {}
    groups: Dict[str, List] = {}
    for j in jobs:
        groups.setdefault(getattr(j, "tenant", "default"), []).append(j)
    out: Dict[str, Dict[str, float]] = {}
    for tenant, members in sorted(groups.items()):
        s = summarize(members)
        target = slo_targets.get(tenant)
        if target is not None:
            s["slo_target"] = float(target)
            s["slo_attainment"] = (
                sum(1 for j in members if j.jct() <= target) / len(members))
        out[tenant] = s
    return out
