"""Model zoo: assigned architectures + the predictor encoder."""
from repro.models.transformer import (
    abstract_cache,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)
from repro.models.objective import loss_fn

__all__ = [
    "abstract_cache",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
