"""Live-engine chunked prefill & KV offload: greedy-token identity
against one-shot prefill (dense + moe), mid-prefill decode exclusion,
the loud ring/SWA fallback, bit-exact swap round-trips, and live<->sim
preempt->resume cost parity (``resume_context_tokens`` equals the
simulator's ``recompute_prefill_tokens`` charge)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Job
from repro.engine import EngineConfig, InferenceEngine
from repro.engine.engine import _gather_slots
from repro.models import init_params
from repro.simulate.executor import SimExecutor
from repro.simulate.profiles import PROFILES


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _job(i, n):
    return Job(job_id=i, prompt=f"p{i}",
               prompt_tokens=[11 + (5 * i + k) % 60 for k in range(n)],
               arrival_time=0.0)


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=128, max_output=64, eos_id=-1)
    base.update(kw)
    return EngineConfig(**base)


def _drive(cfg, params, plen, n_out, prefill_chunk, window=6, ecfg=None):
    """Run one job to ``n_out`` generated tokens, returning the stream."""
    eng = InferenceEngine(cfg, params, ecfg or _ecfg())
    j = _job(0, plen)
    out = []
    for _ in range(64):
        toks, fins = eng.run_window([j], window, prefill_chunk=prefill_chunk)
        j.generated.extend(toks[0])
        out.extend(toks[0])
        if fins[0] or len(out) >= n_out:
            break
    return out[:n_out], eng


# --------------------------------------------------------------------------- #
# Chunked prefill == one-shot prefill (greedy tokens)
# --------------------------------------------------------------------------- #


def test_chunked_matches_oneshot_dense(setup):
    cfg, params = setup
    ref, _ = _drive(cfg, params, plen=41, n_out=18, prefill_chunk=None)
    got, eng = _drive(cfg, params, plen=41, n_out=18, prefill_chunk=8)
    assert got == ref
    assert eng.num_chunk_dispatches >= 5            # ceil(41/8) passes ran
    # chunk dispatches reuse the seq-bucket ladder: no trace explosion
    assert eng.num_chunk_traces <= 2


def test_chunked_matches_oneshot_moe():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref, _ = _drive(cfg, params, plen=21, n_out=8, prefill_chunk=None,
                    window=4)
    got, eng = _drive(cfg, params, plen=21, n_out=8, prefill_chunk=6,
                      window=4)
    assert got == ref
    assert eng.num_chunk_dispatches >= 3


def test_midprefill_job_emits_nothing(setup):
    """A chunk-admitted job joins decode only after its final chunk — and
    the already-running batchmate keeps its exact stream meanwhile."""
    cfg, params = setup
    solo = InferenceEngine(cfg, params, _ecfg())
    s = _job(1, 5)
    solo_toks = []
    for _ in range(3):
        t, _ = solo.run_window([s], 4)
        s.generated.extend(t[0])
        solo_toks.extend(t[0])

    eng = InferenceEngine(cfg, params, _ecfg())
    j1, j2 = _job(1, 5), _job(2, 30)
    got = []
    # j1's single chunk lands in window 1 (decode starts the window after),
    # so 4 chunked windows cover solo's 3 decode windows
    for _ in range(4):
        toks, _ = eng.run_window([j1, j2], 4, prefill_chunk=8)
        j1.generated.extend(toks[0])
        j2.generated.extend(toks[1])
        got.extend(toks[0])
        if eng.prefill_incomplete(j2.job_id):
            assert toks[1] == []                    # mid-prefill: no tokens
    assert got == solo_toks
    assert j2.prefilled_tokens <= 30


def test_chunk_fallback_warns_once_on_ring_cache():
    """mixtral's sliding-window (ring) cache can't chunk: loud one-shot
    fallback, warned exactly once, tokens unchanged."""
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ref, _ = _drive(cfg, params, plen=9, n_out=6, prefill_chunk=None,
                    window=3)
    eng = InferenceEngine(cfg, params, _ecfg())
    assert not eng.chunk_supported()
    j = _job(0, 9)
    with pytest.warns(UserWarning, match="prefill_chunk is not supported"):
        toks, _ = eng.run_window([j], 3, prefill_chunk=4)
    j.generated.extend(toks[0])
    with warnings.catch_warnings():
        warnings.simplefilter("error")              # second call: silent
        t2, _ = eng.run_window([j], 3, prefill_chunk=4)
    assert toks[0] + t2[0] == ref
    assert eng.num_chunk_dispatches == 0


# --------------------------------------------------------------------------- #
# KV offload round-trip
# --------------------------------------------------------------------------- #


def test_swap_roundtrip_bit_exact_and_stream_exact(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, _ecfg())
    j0, j1 = _job(3, 9), _job(4, 7)
    toks, _ = eng.run_window([j0, j1], 5)
    j0.generated.extend(toks[0])
    j1.generated.extend(toks[1])
    slot = eng.slot_of[j0.job_id]
    before = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([slot], jnp.int32)))
    assert eng.offload_job(j0.job_id)
    assert eng.has_stash(j0.job_id) and not eng.has_job(j0.job_id)
    toks, _ = eng.run_window([j1], 5)               # j1 runs while j0 is out
    j1.generated.extend(toks[0])
    new_slot = eng.restore_job(j0)
    after = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([new_slot], jnp.int32)))
    for a, b in zip(jax.tree_util.tree_leaves(after),
                    jax.tree_util.tree_leaves(before)):
        assert np.array_equal(a, b), "swap round-trip not bit-exact"
    # the restored job continues the uninterrupted greedy stream
    ref = InferenceEngine(cfg, params, _ecfg())
    rj = _job(3, 9)
    rt, _ = ref.run_window([rj], 5)
    rj.generated.extend(rt[0])
    rt, _ = ref.run_window([rj], 5)
    toks, _ = eng.run_window([j0, j1], 5)
    assert toks[0] == rt[0]
    assert eng.resume_context_tokens == 0           # swap is not a recompute


def test_swap_midprefill_roundtrip(setup):
    """Offloading a job mid-chunked-prefill preserves the chunk cursor:
    the restored job finishes prefill and matches the one-shot stream."""
    cfg, params = setup
    ref, _ = _drive(cfg, params, plen=20, n_out=6, prefill_chunk=None,
                    window=3)
    eng = InferenceEngine(cfg, params, _ecfg())
    j = _job(0, 20)
    eng.run_window([j], 3, prefill_chunk=6)         # one 6-token chunk in
    assert eng.prefill_incomplete(j.job_id)
    cur = eng._prefill_cursor[j.job_id]
    assert eng.offload_job(j.job_id)
    eng.restore_job(j)
    assert eng._prefill_cursor[j.job_id] == cur
    out = []
    for _ in range(16):
        toks, _ = eng.run_window([j], 3, prefill_chunk=6)
        j.generated.extend(toks[0])
        out.extend(toks[0])
        if len(out) >= 6:
            break
    assert out[:6] == ref


# --------------------------------------------------------------------------- #
# Live <-> sim preempt->resume cost parity
# --------------------------------------------------------------------------- #


def _sim_resume_charge(plen, gen, *, policy, prefill_chunk=None):
    """SimExecutor's recompute charge for resuming a (plen, gen) job."""
    ex = SimExecutor(PROFILES["lam13"])
    j = Job(job_id=0, prompt="x", prompt_tokens=[5] * plen, arrival_time=0.0,
            true_output_len=gen + 50, output_tokens=[5] * (gen + 50))
    j.generated = [5] * gen
    j.prefilled_tokens = plen + gen
    ex._resident.setdefault(0, set()).add(0)
    ex._resident_tokens.setdefault(0, {})[0] = j.prefilled_tokens
    if policy == "swap":
        assert ex.offload(0, j)
    else:
        ex.evict(0, j)
    ex.execute(0, [j], 4, 0.0, prefill_chunk=prefill_chunk)
    return ex.recompute_prefill_tokens


@pytest.mark.parametrize("chunk", [None, 4])
def test_resume_cost_parity_recompute(setup, chunk):
    """The live engine's measured resume re-prefill token count equals the
    simulator's recompute charge for the same (prompt, generated) state."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, _ecfg(max_slots=1))
    j = _job(0, 9)
    for _ in range(8):                              # prefill (+chunks) + gen
        toks, _ = eng.run_window([j], 4, prefill_chunk=chunk)
        j.generated.extend(toks[0])
        if j.tokens_generated >= 4:
            break
    gen = j.tokens_generated
    assert gen >= 4
    eng.evict_job(j.job_id)                         # recompute preemption
    j.prefilled_tokens = 0
    assert eng.resume_context_tokens == 0
    for _ in range(8):                              # resume to first emission
        toks, _ = eng.run_window([j], 4, prefill_chunk=chunk)
        if toks[0]:
            break
    live = eng.resume_context_tokens
    assert live == 9 + gen                          # prompt + generated
    assert live == _sim_resume_charge(9, gen, policy="recompute",
                                      prefill_chunk=chunk)


@pytest.mark.parametrize("chunk", [None, 4])
def test_resume_cost_parity_swap(setup, chunk):
    """Swap-resume charges zero recompute on both sides."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, _ecfg(max_slots=1))
    j = _job(0, 9)
    for _ in range(8):
        toks, _ = eng.run_window([j], 4, prefill_chunk=chunk)
        j.generated.extend(toks[0])
        if j.tokens_generated >= 4:
            break
    gen = j.tokens_generated
    assert eng.offload_job(j.job_id)
    toks, _ = eng.run_window([j], 4, prefill_chunk=chunk)   # auto swap-in
    assert eng.resume_context_tokens == 0
    assert _sim_resume_charge(9, gen, policy="swap",
                              prefill_chunk=chunk) == 0


# --------------------------------------------------------------------------- #
# Swap-pool watermark (PreemptionConfig.swap_pool_tokens)
# --------------------------------------------------------------------------- #


def _offload_n(cfg, params, n, *, window=5, plen=9, max_slots=None):
    """Run ``n`` jobs one window each, return (engine, jobs) pre-offload."""
    eng = InferenceEngine(cfg, params, _ecfg(max_slots=max_slots or n))
    jobs = [_job(50 + i, plen) for i in range(n)]
    toks, _ = eng.run_window(jobs, window)
    for j, t in zip(jobs, toks):
        j.generated.extend(t)
    return eng, jobs


def test_swap_pool_unbounded_by_default(setup):
    cfg, params = setup
    eng, jobs = _offload_n(cfg, params, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")              # any warning -> failure
        for j in jobs:
            assert eng.offload_job(j.job_id)
    assert eng.n_stash_evictions == 0
    assert eng.stash_tokens > 0
    assert all(eng.has_stash(j.job_id) for j in jobs)


def test_swap_pool_evicts_coldest_with_warning(setup):
    cfg, params = setup
    eng, jobs = _offload_n(cfg, params, 3)
    assert eng.offload_job(jobs[0].job_id)
    ctx = eng.stash_tokens                           # one stash's footprint
    # pool fits exactly two stashes: the third swap-out must evict the
    # COLDEST victim (jobs[0], the oldest swap-out), not a newer one
    eng.swap_pool_tokens = 2 * ctx
    assert eng.offload_job(jobs[1].job_id)
    with pytest.warns(UserWarning, match=r"swap pool exceeded"):
        assert eng.offload_job(jobs[2].job_id)
    assert not eng.has_stash(jobs[0].job_id)
    assert eng.has_stash(jobs[1].job_id)
    assert eng.has_stash(jobs[2].job_id)
    assert eng.n_stash_evictions == 1
    assert eng.stash_evicted_tokens == ctx
    assert eng.stash_tokens == 2 * ctx
    # the evicted victim's stash is GONE — resume goes through the
    # recompute-fallback path, not a silent stale restore
    with pytest.raises(KeyError):
        eng.restore_job(jobs[0])


def test_swap_pool_refuses_oversized_fresh_stash(setup):
    cfg, params = setup
    eng, jobs = _offload_n(cfg, params, 1)
    eng.swap_pool_tokens = 1                         # smaller than any stash
    with pytest.warns(UserWarning, match=r"recompute-fallback"):
        assert not eng.offload_job(jobs[0].job_id)   # caller falls back
    assert eng.stash_tokens == 0 and len(eng._host_stash) == 0
    assert eng.n_stash_evictions == 1
    assert not eng.has_job(jobs[0].job_id)           # still evicted


def test_swap_pool_accounting_roundtrip(setup):
    cfg, params = setup
    eng, jobs = _offload_n(cfg, params, 2)
    assert eng.offload_job(jobs[0].job_id)
    assert eng.offload_job(jobs[1].job_id)
    total = eng.stash_tokens
    assert total > 0
    eng.restore_job(jobs[0])
    mid = eng.stash_tokens
    assert 0 < mid < total
    eng.drop_stash(jobs[1].job_id)
    assert eng.stash_tokens == 0


def test_executor_threads_watermark_and_counters(setup):
    from repro.engine.engine import EngineExecutor

    cfg, params = setup
    eng, jobs = _offload_n(cfg, params, 2)
    ex = EngineExecutor({0: eng}, swap_pool_tokens=123)
    assert eng.swap_pool_tokens == 123
    c = ex.counters()
    assert c["stash_evictions"] == 0
    assert c["stash_evicted_tokens"] == 0
    # None leaves engine-level settings untouched
    EngineExecutor({0: eng})
    assert eng.swap_pool_tokens == 123
