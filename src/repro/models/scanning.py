"""Layer-scan control.

``lax.scan`` keeps HLO small (essential for 512-way SPMD compiles), but XLA's
``cost_analysis`` counts a while-loop body ONCE regardless of trip count.
The roofline cost probes therefore lower small-layer-count variants with
scans fully unrolled (``unrolled()`` context) and extrapolate per-layer
costs; production lowering keeps rolled scans.
"""
from __future__ import annotations

import contextlib

import jax

_UNROLL = False


@contextlib.contextmanager
def unrolled():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def layer_scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length, unroll=_UNROLL)
