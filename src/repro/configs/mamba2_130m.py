"""Mamba2-130M [arXiv:2405.21060] — attention-free SSM with SSD.

24L, d_model 768, d_state 128, expand 2, head_dim 64, vocab 50280.
Sub-quadratic by construction: long_500k decode runs natively (O(1) state).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="mamba2-130m",
        family="ssm",
        source="arXiv:2405.21060",
        n_layers=24,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        rope_type="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                      chunk_size=256),
        long_context_mode="native",
        max_position_embeddings=1 << 20,
    )
)
