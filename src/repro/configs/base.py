"""Configuration system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` — a frozen
dataclass that fully determines parameter shapes, the forward functions that
apply, and the sharding rules used by the launcher.  Configs are registered in
a global registry keyed by ``arch_id`` so launchers/tests/benchmarks can select
them with ``--arch <id>``.

Design notes
------------
* ``family`` selects the block structure (dense / moe / ssm / hybrid / vlm /
  audio).  ``vlm`` and ``audio`` reuse the dense decoder stack; their modality
  frontend is a stub per the reproduction spec (``input_specs`` hands the model
  precomputed patch/frame embeddings).
* ``reduced()`` produces the CPU-smoke-testable variant of the same family
  (<=2 layers, d_model<=512, <=4 experts) used by the per-arch smoke tests.
* The FULL configs are only ever touched abstractly (``jax.eval_shape`` /
  ``.lower()``), never materialised on the host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int = 0
    top_k: int = 0
    #: experts always active regardless of routing (qwen2-moe "shared" experts)
    num_shared_experts: int = 0
    #: FFN hidden dim of each routed expert (may differ from dense d_ff)
    expert_d_ff: int = 0
    #: FFN hidden dim of the shared-expert path (qwen2-moe: shared = 4x expert)
    shared_d_ff: int = 0
    #: weight of the load-balancing auxiliary loss (Switch-style)
    router_aux_weight: float = 0.01
    #: normalise top-k router weights to sum to 1 (mixtral: True)
    norm_topk_prob: bool = True

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    #: A init range (discretised negative real eigenvalues)
    a_init_range: Tuple[float, float] = (1.0, 16.0)

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block.

    Every ``attn_every`` backbone layers, one *shared* (weight-tied) attention
    block is applied (arXiv:2411.15242).  ``n_shared_blocks`` distinct shared
    blocks are cycled through if >1.
    """

    attn_every: int = 0
    n_shared_blocks: int = 1

    @property
    def enabled(self) -> bool:
        return self.attn_every > 0


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for encoder-decoder (whisper) architectures."""

    n_layers: int = 0
    #: number of positions the (stubbed) conv frontend produces per sample
    n_frames: int = 0

    @property
    def enabled(self) -> bool:
        return self.n_layers > 0


@dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation of the public config

    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    activation: str = "silu"  # silu (gated) | gelu (whisper's plain MLP)
    gated_mlp: bool = True

    rope_type: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10000.0
    #: M-RoPE section split (temporal, height, width) for qwen2-vl
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    max_position_embeddings: int = 131072

    attention_type: str = "full"  # full | swa
    swa_window: int = 4096
    #: how the arch serves 500k-token decode: "native" (ssm/swa), or
    #: "sliding_window" (explicit beyond-config carve-in), or "unsupported"
    long_context_mode: str = "sliding_window"

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    encoder: EncoderConfig = field(default_factory=EncoderConfig)

    #: modality frontend stub: none | vision_stub | audio_stub
    frontend: str = "none"
    #: number of stub embeddings injected per request (patches / frames)
    frontend_tokens: int = 0

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            if self.n_heads:
                object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm.enabled else 0

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm.head_dim if self.ssm.enabled else 0

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic total parameter count (embedding included once if tied)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            attn += self.n_heads * self.head_dim * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        else:
            attn = 0
        if self.moe.enabled:
            e = self.moe
            ffn = e.num_experts * 3 * d * e.expert_d_ff
            ffn += e.num_shared_experts * 3 * d * e.shared_d_ff
            ffn += d * e.num_experts  # router
        elif self.d_ff:
            ffn = (3 if self.gated_mlp else 2) * d * self.d_ff
        else:
            ffn = 0
        if self.family in ("ssm", "hybrid") and self.ssm.enabled:
            di = self.ssm_d_inner
            nh = self.ssm_n_heads
            g = self.ssm.n_groups * self.ssm.d_state
            ssm = d * (2 * di + 2 * g + nh)  # in_proj (z,x,B,C,dt)
            ssm += (di + 2 * g) * self.ssm.d_conv  # conv1d
            ssm += 2 * nh + di  # A_log, dt_bias, skip D
            ssm += di * d  # out_proj
        else:
            ssm = 0
        norms = 2 * d

        if self.family == "hybrid" and self.hybrid.enabled:
            # backbone layers are SSM; shared attention blocks counted once
            n_shared = self.hybrid.n_shared_blocks
            shared = n_shared * (attn + (3 if self.gated_mlp else 2) * d * self.d_ff + 2 * d)
            total_layers = self.n_layers * (ssm + norms)
            body = total_layers + shared
        elif self.family == "ssm":
            body = self.n_layers * (ssm + norms)
        else:
            body = self.n_layers * (attn + ffn + norms)
        enc = 0
        if self.encoder.enabled:
            enc_attn = 4 * d * d
            enc_ffn = 2 * d * self.d_ff
            enc = self.encoder.n_layers * (enc_attn + enc_ffn + 2 * d)
            # decoder cross-attention
            body += self.n_layers * (4 * d * d + d)
        return emb + body + enc + d  # final norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k + shared experts)."""
        if not self.moe.enabled:
            return self.param_count()
        e = self.moe
        full_ffn = e.num_experts * 3 * self.d_model * e.expert_d_ff
        act_ffn = e.top_k * 3 * self.d_model * e.expert_d_ff
        return self.param_count() - self.n_layers * (full_ffn - act_ffn)

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Smoke-scale variant of the same family for CPU tests."""
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4) or 4
        head_dim = max(d_model // n_heads, 16)
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0
        kw: Dict = dict(
            arch_id=self.arch_id + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_position_embeddings=2048,
            swa_window=64,
            frontend_tokens=min(self.frontend_tokens, 8),
            dtype="float32",
        )
        if self.moe.enabled:
            kw["moe"] = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=min(self.moe.expert_d_ff, 128),
                shared_d_ff=min(self.moe.shared_d_ff, 128),
            )
        if self.ssm.enabled:
            kw["ssm"] = replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), head_dim=32,
                chunk_size=32,
            )
        if self.hybrid.enabled:
            kw["n_layers"] = 4
            kw["hybrid"] = replace(self.hybrid, attn_every=2)
        if self.encoder.enabled:
            kw["encoder"] = replace(self.encoder, n_layers=2, n_frames=16)
        if self.rope_type == "mrope":
            kw["mrope_sections"] = _mrope_sections_for(head_dim)
        return replace(self, **kw)


def _mrope_sections_for(head_dim: int) -> Tuple[int, int, int]:
    half = head_dim // 2
    t = half // 2
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, ModelConfig] = {}


def register(config: ModelConfig) -> ModelConfig:
    if config.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch_id {config.arch_id!r}")
    _REGISTRY[config.arch_id] = config
    return config


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every per-arch module for its registration side effect
    from repro.configs import (  # noqa: F401
        llama3_2_3b,
        mamba2_130m,
        mixtral_8x7b,
        qwen1_5_32b,
        qwen2_1_5b,
        qwen2_moe_a2_7b,
        qwen2_vl_7b,
        whisper_large_v3,
        yi_6b,
        zamba2_7b,
    )
