"""Cluster scheduling: prediction-aware placement over backend workers.

The paper deploys ELIS cloud-natively (§4.1): the frontend consults the
global state G and load-balances every new request across Kubernetes pods
(StatefulSet pod identity maps to the integer node id).  This module is
that cluster layer:

* :class:`GlobalState` — the frontend's shared-memory view of the cluster:
  per-node live-job counts, per-node outstanding *predicted remaining
  tokens* (kept in sync by the scheduler on assign / re-score / finish /
  preempt / cancel), and the ``busy_until`` horizon each node's executing
  window runs to;
* placement policies — :class:`LeastJobsPlacement` (the original greedy
  job-counter, kept for ablation), :class:`LeastPredictedWorkPlacement`
  (length-prediction-aware placement a la Qiu et al.: balance outstanding
  predicted tokens, not request counts), and :class:`LeastEtaPlacement`
  (estimated time to drain the node's backlog, using per-node token costs
  from the calibrated latency profiles — the policy that separates fast
  from slow pods in a heterogeneous cluster);
* :class:`LoadBalancer` — applies the selected placement at arrival.

Cross-node *rebalancing* (work-stealing of queued jobs at ``node_free``
events) lives in :class:`repro.core.frontend.ELISFrontend`, which owns the
per-node queues being migrated.
"""
from __future__ import annotations

from typing import Dict, Optional


class GlobalState:
    """The frontend's shared-memory view of the cluster (paper's G).

    Tracks, per node: live-job count, outstanding predicted remaining
    tokens, and the time horizon the node's currently executing window runs
    to.  Per-job work contributions are keyed by ``job_id`` so retractions
    (finish / cancel / expiry / migration) are exact — totals return to
    zero once every admitted job is terminal (:meth:`assert_drained`).
    """

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.active_jobs: Dict[int, int] = {n: 0 for n in range(n_nodes)}
        #: outstanding predicted remaining tokens per node
        self.predicted_work: Dict[int, float] = {n: 0.0 for n in range(n_nodes)}
        #: serving-clock time the node's executing window completes at;
        #: monotone per node (windows execute back to back)
        self.busy_until: Dict[int, float] = {n: 0.0 for n in range(n_nodes)}
        self._job_node: Dict[int, int] = {}
        self._job_work: Dict[int, float] = {}

    # ------------------------------------------------------------------ #
    def add_job(self, node: int, job_id: int, work: float = 0.0) -> None:
        assert job_id not in self._job_node, f"job {job_id} already placed"
        self.active_jobs[node] += 1
        self.predicted_work[node] += work
        self._job_node[job_id] = node
        self._job_work[job_id] = work

    def set_work(self, job_id: int, work: float) -> None:
        """Refresh a live job's predicted-remaining-tokens contribution
        (called by the scheduler after each scoring pass)."""
        node = self._job_node[job_id]
        self.predicted_work[node] += work - self._job_work[job_id]
        self._job_work[job_id] = work

    def work_of(self, job_id: int) -> float:
        return self._job_work[job_id]

    def node_of(self, job_id: int) -> int:
        return self._job_node[job_id]

    def move_job(self, job_id: int, dst: int) -> None:
        """Migrate a job's accounting to another node (work-stealing)."""
        src = self._job_node[job_id]
        if src == dst:
            return
        w = self._job_work[job_id]
        self.active_jobs[src] -= 1
        self.predicted_work[src] -= w
        self.active_jobs[dst] += 1
        self.predicted_work[dst] += w
        self._job_node[job_id] = dst

    def finish_job(self, node: int, job_id: int) -> None:
        """Retract a terminal job (FINISHED / CANCELLED / EXPIRED) — both
        the live count and its predicted-work contribution."""
        assert self._job_node.get(job_id) == node, (
            f"job {job_id} is on node {self._job_node.get(job_id)}, "
            f"not {node}")
        self.active_jobs[node] -= 1
        assert self.active_jobs[node] >= 0
        self.predicted_work[node] -= self._job_work.pop(job_id)
        del self._job_node[job_id]

    def note_busy(self, node: int, until: float) -> None:
        """Record the horizon of the window ``node`` just started executing.
        Windows run back to back, so the horizon is monotone per node."""
        assert until >= self.busy_until[node], (
            f"busy_until must be monotone per node: node {node} "
            f"{self.busy_until[node]} -> {until}")
        self.busy_until[node] = until

    def assert_drained(self) -> None:
        """Invariant: with every admitted job terminal, totals are zero."""
        assert not self._job_node, (
            f"{len(self._job_node)} jobs still accounted: "
            f"{sorted(self._job_node)[:8]}")
        assert all(c == 0 for c in self.active_jobs.values()), self.active_jobs
        assert all(abs(w) < 1e-6 for w in self.predicted_work.values()), \
            self.predicted_work


# --------------------------------------------------------------------------- #
# Placement policies
# --------------------------------------------------------------------------- #


class PlacementPolicy:
    """Chooses the node for a newly arrived job."""

    name = "least_jobs"
    #: True when the policy reads predicted work — the frontend only spends
    #: an arrival-time prediction when some consumer needs it
    uses_work = False

    def select(self, state: GlobalState, job, estimate: float,
               now: float) -> int:
        raise NotImplementedError


class LeastJobsPlacement(PlacementPolicy):
    """Greedy min-job-count (paper §4.1 line 3 — the original balancer)."""

    name = "least_jobs"

    def select(self, state: GlobalState, job, estimate: float,
               now: float) -> int:
        return min(state.active_jobs,
                   key=lambda n: (state.active_jobs[n], n))


class LeastPredictedWorkPlacement(PlacementPolicy):
    """Balance outstanding *predicted tokens*, not request counts.

    Length-prediction-aware placement (Qiu et al.): a node holding three
    10-token answers is emptier than one holding a single 900-token essay,
    which the job counter cannot see.
    """

    name = "least_predicted_work"
    uses_work = True

    def select(self, state: GlobalState, job, estimate: float,
               now: float) -> int:
        return min(state.predicted_work,
                   key=lambda n: (state.predicted_work[n],
                                  state.active_jobs[n], n))


class LeastEtaPlacement(PlacementPolicy):
    """Minimise the estimated time for the node to drain its backlog plus
    this job: ``max(busy_until - now, 0) + (work + estimate) * token_cost``.

    ``token_cost`` is seconds per generated token on that node (from the
    calibrated :mod:`repro.simulate.profiles` latency model), which is what
    distinguishes fast from slow pods in a heterogeneous cluster — the only
    policy here that does.
    """

    name = "least_eta"
    uses_work = True

    def __init__(self, node_token_cost: Optional[Dict[int, float]] = None):
        self.node_token_cost = dict(node_token_cost or {})
        costs = list(self.node_token_cost.values())
        self._default_cost = sum(costs) / len(costs) if costs else 1.0

    def eta(self, state: GlobalState, node: int, extra_tokens: float,
            now: float) -> float:
        cost = self.node_token_cost.get(node, self._default_cost)
        backlog = max(state.busy_until[node] - now, 0.0)
        return backlog + (state.predicted_work[node] + extra_tokens) * cost

    def select(self, state: GlobalState, job, estimate: float,
               now: float) -> int:
        return min(state.predicted_work,
                   key=lambda n: (self.eta(state, n, estimate, now),
                                  state.active_jobs[n], n))


PLACEMENTS = {
    p.name: p for p in (LeastJobsPlacement, LeastPredictedWorkPlacement,
                        LeastEtaPlacement)
}


def make_placement(name: str,
                   node_token_cost: Optional[Dict[int, float]] = None
                   ) -> PlacementPolicy:
    try:
        cls = PLACEMENTS[name]
    except KeyError:
        raise ValueError(
            f"unknown placement {name!r} (have {sorted(PLACEMENTS)})"
        ) from None
    if cls is LeastEtaPlacement:
        return cls(node_token_cost)
    return cls()


# --------------------------------------------------------------------------- #


class LoadBalancer:
    """Applies the placement policy at arrival and books the assignment."""

    def __init__(self, state: GlobalState,
                 placement: Optional[PlacementPolicy] = None):
        self.state = state
        self.placement = placement or LeastJobsPlacement()

    def assign(self, job, estimate: float = 0.0, now: float = 0.0) -> int:
        node = self.placement.select(self.state, job, estimate, now)
        job.node = node
        self.state.add_job(node, job.job_id, estimate)
        return node
