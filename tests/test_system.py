"""End-to-end system behaviour: live engine + real predictor + ISRTF frontend.

The full paper pipeline at reduced scale: a trained BGE-style predictor
drives ISRTF scheduling of a live JAX engine through the ELIS frontend,
and the outputs are byte-identical to unscheduled greedy decoding.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (
    BGEPredictor,
    ELISFrontend,
    FrontendConfig,
    Job,
    OraclePredictor,
    PredictorConfig,
    PreemptionConfig,
    SchedulerConfig,
)
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import forward, init_params
from repro.models.encoder import EncoderArchConfig


@pytest.fixture(scope="module")
def live_system():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=256, max_output=30, eos_id=-1))
    return cfg, params, engine


def test_live_elis_end_to_end(live_system):
    cfg, params, engine = live_system
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy="isrtf", window=10,
                                      batch_size=2),
            preemption=PreemptionConfig(enabled=True),
        ),
        OraclePredictor(),
        EngineExecutor({0: engine}),
    )
    jobs = [
        Job(job_id=i, prompt=f"p{i}", prompt_tokens=[10 + i, 20 + i],
            arrival_time=0.0, true_output_len=30)
        for i in range(3)
    ]
    for j in jobs:
        fe.submit(j)
    done = fe.run()
    assert len(done) == 3
    # every job's stream equals isolated greedy decoding of its prompt
    for j in done:
        toks = list(j.prompt_tokens)
        want = []
        for _ in range(len(j.generated)):
            logits, _ = forward(params, cfg, {"tokens": jnp.asarray([toks])})
            nxt = int(jnp.argmax(logits[0, -1]))
            want.append(nxt)
            toks.append(nxt)
        assert j.generated == want, j.job_id
        assert j.finish_time is not None and j.jct() > 0


def test_bge_predictor_drives_isrtf(live_system):
    """ISRTF with the *real* (untrained) BGE predictor still completes all
    jobs correctly — scheduler correctness is independent of predictor
    quality (the paper's fallback property)."""
    cfg, params, engine = live_system
    pred = BGEPredictor(PredictorConfig(
        encoder=EncoderArchConfig(d_model=32, n_heads=2, n_layers=1, d_ff=64,
                                  max_len=64),
        fc_hidden=32, max_len=64))
    engine2 = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=256, max_output=12, eos_id=-1))
    fe = ELISFrontend(
        FrontendConfig(n_nodes=1,
                       scheduler=SchedulerConfig(policy="isrtf", window=6,
                                                 batch_size=2)),
        pred,
        EngineExecutor({0: engine2}),
    )
    for i in range(3):
        fe.submit(Job(job_id=i, prompt="q", prompt_tokens=[5, 6, 7 + i],
                      arrival_time=float(i) * 0.01, true_output_len=12))
    done = fe.run()
    assert len(done) == 3
    for j in done:
        assert j.tokens_generated == 12
        assert len(j.predictions) >= 2  # re-predicted every iteration
