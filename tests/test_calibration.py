"""Distribution-aware predictor API: LengthPrediction quantile math, online
feedback calibration (EMA debias / conformal), risk-aware scoring, and the
trace-identity guarantee of the new ``predict()`` path vs the legacy
``init``/``iter`` scalar protocol."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CalibrationConfig,
    ConformalPredictor,
    EMADebiasedPredictor,
    Job,
    JobState,
    LengthPrediction,
    LengthPredictor,
    NoisyOraclePredictor,
    OraclePredictor,
    SchedulerConfig,
    make_policy,
    make_predictor,
    predict_lengths,
    wrap_calibration,
)
from repro.core.predictor import QUANTILE_GRID, _norm_ppf
from repro.core.scheduler import (
    cached_expected_remaining,
    cached_raw_priority,
    score_pool,
)


def mk_job(jid, true_len=100, arrival=0.0, generated=0):
    j = Job(job_id=jid, prompt=f"p{jid}", prompt_tokens=[1, 2],
            arrival_time=arrival, true_output_len=true_len)
    j.generated = [7] * generated
    return j


def finish(job):
    """Run the job to its true length and mark it FINISHED."""
    job.generated = [7] * job.true_output_len
    job.state = JobState.FINISHED
    job.finished = True
    return job


class ScaledOracle(LengthPredictor):
    """Deterministic oracle scaled by a (possibly step-dependent) factor —
    the controllable miscalibration for wrapper tests."""

    def __init__(self, factor=0.5, step_factors=None):
        self.factor = factor
        self.step_factors = step_factors or {}

    def _point(self, job):
        from repro.data.dataset import WINDOW

        f = self.step_factors.get(job.tokens_generated // WINDOW, self.factor)
        return max(float(job.true_remaining) * f, 1.0)


class LegacyShim:
    """A predictor exposing ONLY the deprecated scalar protocol — forces
    predict_lengths down the legacy per-job branch."""

    def __init__(self, inner):
        self._inner = inner

    def init(self, job):
        return self._inner.init(job)

    def iter(self, job):
        return self._inner.iter(job)


# --------------------------------------------------------------------------- #
# LengthPrediction / quantile math
# --------------------------------------------------------------------------- #


def test_norm_ppf_matches_known_values():
    assert _norm_ppf(0.5) == pytest.approx(0.0, abs=1e-9)
    assert _norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-4)
    assert _norm_ppf(0.9) == pytest.approx(1.281552, abs=1e-4)
    assert _norm_ppf(0.1) == pytest.approx(-_norm_ppf(0.9), abs=1e-9)
    with pytest.raises(ValueError):
        _norm_ppf(0.0)


def test_length_prediction_quantile_fallbacks():
    # degenerate: no ladder, no spread -> the mean at every risk level
    p = LengthPrediction(mean=50.0)
    assert p.quantile(0.5) == p.quantile(0.99) == 50.0
    # spread, no ladder -> normal approximation
    p = LengthPrediction(mean=50.0, std=10.0)
    assert p.quantile(0.9) == pytest.approx(50.0 + 1.281552 * 10.0, rel=1e-4)
    assert p.quantile(0.5) == pytest.approx(50.0, abs=1e-6)


def test_length_prediction_ladder_interpolation():
    lad = ((0.5, 100.0), (0.9, 200.0))
    p = LengthPrediction(mean=100.0, quantiles=lad)
    assert p.quantile(0.5) == 100.0
    assert p.quantile(0.9) == 200.0
    assert p.quantile(0.7) == pytest.approx(150.0)
    assert p.quantile(0.3) == 100.0   # below the ladder: clamp to first rung
    assert p.quantile(0.99) == 200.0  # above: clamp to last


def test_oracle_predictions_are_degenerate():
    o = OraclePredictor()
    jobs = [mk_job(0, 77), mk_job(1, 13)]
    preds = o.predict(jobs)
    assert [p.mean for p in preds] == [77.0, 13.0]
    assert all(p.quantile(0.95) == p.mean for p in preds)
    # deprecated shims still answer
    assert o.init(jobs[0]) == 77.0
    jobs[0].generated = [5] * 30
    assert o.iter(jobs[0]) == 47.0


def test_noisy_oracle_predict_matches_legacy_draw_order():
    """The batched predict() must draw RNG per job in pool order — the exact
    sequence the legacy per-job init/iter path produced."""
    jobs = [mk_job(i, 50 + 17 * i) for i in range(8)]
    a = NoisyOraclePredictor(seed=42)
    batched = [p.mean for p in a.predict(jobs)]
    b = NoisyOraclePredictor(seed=42)
    legacy = [b.init(j) for j in jobs]
    assert batched == legacy


def test_noisy_oracle_quantiles_analytic_no_extra_rng():
    pred = NoisyOraclePredictor(seed=0)
    j = mk_job(0, 200)
    [p] = pred.predict([j])
    s = pred._sigma(0)
    # analytic lognormal posterior: q-quantile = m * exp(s^2/2 + s z_q)
    assert p.quantile(0.9) == pytest.approx(
        p.mean * math.exp(0.5 * s * s + s * 1.281552), rel=1e-4)
    # ladder is monotone and the upper tail exceeds the point estimate
    vals = [p.quantile(q) for q in QUANTILE_GRID]
    assert vals == sorted(vals)
    assert p.quantile(0.9) > p.mean
    # quantile evaluation drew no RNG: the next draw matches a fresh
    # predictor that never touched quantiles
    fresh = NoisyOraclePredictor(seed=0)
    fresh.init(mk_job(0, 200))
    assert pred.init(mk_job(1, 100)) == fresh.init(mk_job(1, 100))


def test_noisy_oracle_bias_default_is_bit_exact():
    a = NoisyOraclePredictor(seed=7)
    b = NoisyOraclePredictor(seed=7, bias=1.0)
    jobs = [mk_job(i, 30 + i) for i in range(6)]
    assert [p.mean for p in a.predict(jobs)] == \
        [p.mean for p in b.predict(jobs)]


# --------------------------------------------------------------------------- #
# EMA debiasing
# --------------------------------------------------------------------------- #


def test_ema_debias_drives_multiplicative_bias_to_one():
    """Under a constantly biased base (pred = 0.5 * truth) the correction
    converges to 2x: served predictions become unbiased."""
    rng = np.random.RandomState(0)
    wrapped = EMADebiasedPredictor(
        ScaledOracle(0.5), CalibrationConfig(debias=True, ema_alpha=0.2,
                                             min_samples=8, by_step=False))
    for i in range(80):
        L = int(rng.randint(20, 400))
        j = mk_job(i, L)
        wrapped.predict([j])
        finish(j)
        wrapped.observe(j, 0.0)
    assert wrapped.bias(0) == pytest.approx(0.5, rel=0.05)
    # held-out: corrected predictions are ~unbiased
    ratios = []
    for i in range(100, 140):
        L = int(rng.randint(20, 400))
        [p] = wrapped.predict([mk_job(i, L)])
        ratios.append(p.mean / L)
    gmean = math.exp(np.mean(np.log(ratios)))
    assert gmean == pytest.approx(1.0, rel=0.05)


def test_ema_debias_per_step_buckets():
    """Step-dependent bias (Fig. 2(b): the error profile varies with the
    iteration index) is corrected per step bucket."""
    from repro.data.dataset import WINDOW

    base = ScaledOracle(step_factors={0: 0.5, 1: 2.0})
    wrapped = EMADebiasedPredictor(
        base, CalibrationConfig(debias=True, ema_alpha=0.3, min_samples=5,
                                by_step=True))
    rng = np.random.RandomState(1)
    for i in range(60):
        L = int(rng.randint(150, 400))
        j = mk_job(i, L)
        wrapped.predict([j])                    # step-0 prediction
        j.generated = [7] * WINDOW
        wrapped.predict([j])                    # step-1 prediction
        finish(j)
        wrapped.observe(j, 0.0)
    assert wrapped.bias(0) == pytest.approx(0.5, rel=0.1)
    assert wrapped.bias(1) == pytest.approx(2.0, rel=0.1)
    j0, j1 = mk_job(900, 300), mk_job(901, 300, generated=WINDOW)
    [p0] = wrapped.predict([j0])
    [p1] = wrapped.predict([j1])
    assert p0.mean == pytest.approx(j0.true_remaining, rel=0.1)
    assert p1.mean == pytest.approx(j1.true_remaining, rel=0.1)


# --------------------------------------------------------------------------- #
# Conformal quantiles
# --------------------------------------------------------------------------- #


@given(st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_conformal_quantile_achieves_coverage(seed):
    """Distribution-free guarantee: on exchangeable residuals the q-quantile
    upper bound covers the realised length with empirical frequency >= q,
    up to sampling noise from BOTH the calibration window (empirical
    quantile estimation) and the held-out binomial (3.5 sigma combined;
    measured over 30 seeds the empirical mean is 0.697 / 0.900 with minima
    0.637 / 0.867 — the slack floor sits well below both)."""
    rng = np.random.RandomState(seed)
    base = NoisyOraclePredictor(seed=seed + 1)
    wrapped = ConformalPredictor(
        base, CalibrationConfig(conformal=True, window=2000, min_samples=30,
                                by_step=False))
    n_cal, n_test = 1200, 400
    for i in range(n_cal):
        L = int(rng.randint(20, 500))
        j = mk_job(i, L)
        wrapped.predict([j])
        finish(j)
        wrapped.observe(j, 0.0)
    for q in (0.7, 0.9):
        covered = 0
        for i in range(n_test):
            L = int(rng.randint(20, 500))
            [p] = wrapped.predict([mk_job(10_000 + i, L)])
            if p.quantile(q) >= L:
                covered += 1
        slack = 3.5 * math.sqrt(q * (1 - q)) * math.sqrt(
            1.0 / n_cal + 1.0 / n_test)
        assert covered / n_test >= q - slack, (q, covered / n_test)


def test_conformal_mean_passthrough_and_cold_fallback():
    base = NoisyOraclePredictor(seed=3)
    ref = NoisyOraclePredictor(seed=3)
    wrapped = ConformalPredictor(base)
    jobs = [mk_job(i, 100) for i in range(4)]
    got = [p.mean for p in wrapped.predict(jobs)]
    want = [p.mean for p in ref.predict(jobs)]
    assert got == want                       # point estimate untouched
    # cold window: the base's analytic ladder is served unchanged
    [p] = wrapped.predict([mk_job(9, 100)])
    [b] = ref.predict([mk_job(9, 100)])
    assert p.quantiles == b.quantiles


def test_observe_after_cancel_or_expiry_does_not_poison():
    """Aborted requests have censored lengths — the residual window and the
    bias estimate must ignore them entirely."""
    for state in (JobState.CANCELLED, JobState.EXPIRED):
        wrapped = wrap_calibration(
            ScaledOracle(0.5),
            CalibrationConfig(debias=True, conformal=True, min_samples=1))
        ema = wrapped.base
        assert isinstance(ema, EMADebiasedPredictor)
        j = mk_job(0, 400)
        wrapped.predict([j])
        j.generated = [7] * 30              # aborted after 30 of 400 tokens
        j.state = state
        wrapped.observe(j, 0.0)
        assert wrapped.n_observed == 0
        assert ema.n_observed == 0
        assert all(len(d) == 0 for d in wrapped._scores)
        assert j.job_id not in wrapped._pending
        assert j.job_id not in ema._pending


def test_observe_mid_flight_resolves_residuals_once():
    wrapped = ConformalPredictor(
        OraclePredictor(), CalibrationConfig(conformal=True, min_samples=1))
    j = mk_job(0, 200)
    wrapped.predict([j])
    j.generated = [7] * 50
    wrapped.observe(j, float(j.true_remaining))   # window boundary feedback
    assert wrapped.n_observed == 1
    wrapped.observe(j, float(j.true_remaining))   # no pending -> no double
    assert wrapped.n_observed == 1
    finish(j)
    wrapped.observe(j, 0.0)
    assert wrapped.n_observed == 1                # nothing new logged
    assert j.job_id not in wrapped._pending       # terminal cleanup


# --------------------------------------------------------------------------- #
# Registry / composition
# --------------------------------------------------------------------------- #


def test_make_predictor_registry_and_composition():
    assert make_predictor("none") is None
    assert isinstance(make_predictor("oracle"), OraclePredictor)
    p = make_predictor("noisy_oracle", seed=5, bias=0.5)
    assert isinstance(p, NoisyOraclePredictor) and p.bias == 0.5
    c = make_predictor("noisy_oracle", calibration="ema+conformal")
    assert isinstance(c, ConformalPredictor)
    assert isinstance(c.base, EMADebiasedPredictor)
    assert isinstance(c.base.base, NoisyOraclePredictor)
    # unknown-name errors list the valid choices, including the ranked kind
    with pytest.raises(ValueError, match=r"ranked"):
        make_predictor("nope")
    with pytest.raises(ValueError):
        make_predictor("bge")  # needs bge=
    with pytest.raises(ValueError, match=r"conformal"):
        CalibrationConfig.from_name("bogus")
    cfg = CalibrationConfig.from_name("ema")
    assert cfg.debias and not cfg.conformal


def test_predict_lengths_adapts_legacy_predictors():
    legacy = LegacyShim(OraclePredictor())
    jobs = [mk_job(0, 60), mk_job(1, 90)]
    preds = predict_lengths(legacy, jobs)
    assert [p.mean for p in preds] == [60.0, 90.0]
    assert all(isinstance(p, LengthPrediction) for p in preds)


# --------------------------------------------------------------------------- #
# Risk-aware scoring
# --------------------------------------------------------------------------- #


def test_risk_quantile_ranks_on_upper_quantile_keeps_expectation():
    pol = make_policy(SchedulerConfig(policy="isrtf", risk_quantile=0.9),
                      NoisyOraclePredictor(seed=0))
    jobs = [mk_job(i, 100 + 50 * i) for i in range(3)]
    score_pool(pol, [], jobs, now=0.0)
    for j in jobs:
        assert j.priority > j.expected_remaining  # quantile hedges upward
        # work accounting consumes the expectation, ranking the quantile
        assert cached_expected_remaining(j) == j.expected_remaining
        assert cached_raw_priority(j) == j.priority
        assert j.pred_trace == [(0, j.expected_remaining)]


def test_risk_none_priority_equals_expectation():
    pol = make_policy(SchedulerConfig(policy="isrtf"),
                      NoisyOraclePredictor(seed=0))
    jobs = [mk_job(i, 120) for i in range(4)]
    score_pool(pol, [], jobs, now=0.0)
    assert all(j.priority == j.expected_remaining for j in jobs)


def test_risk_quantile_deprioritises_uncertain_jobs():
    """Two jobs with equal point estimates: the one at a deeper iteration
    step (lower sigma) outranks the fresh, uncertain one under risk-aware
    scoring — hedging against early-step mispredictions."""
    from repro.data.dataset import WINDOW

    pred = NoisyOraclePredictor(seed=0)
    fresh, deep = mk_job(0, 100), mk_job(1, 100 + WINDOW, generated=WINDOW)
    m = 80.0
    pf = pred._prediction(fresh, m)
    pd = pred._prediction(deep, m)
    assert pf.quantile(0.9) > pd.quantile(0.9)


# --------------------------------------------------------------------------- #
# End-to-end: feedback through the serving loop + trace identity
# --------------------------------------------------------------------------- #


def _drain_once(predictor, *, risk_quantile=None, n=40, seed=11):
    """Small drain-once cluster sim; returns {rid: (jct, tokens, preempts)}."""
    from repro.core import (
        ElisServer,
        FrontendConfig,
        PreemptionConfig,
        api,
    )
    from repro.data.arrivals import GammaArrivals
    from repro.data.workload import WorkloadGenerator
    from repro.simulate.executor import SimExecutor
    from repro.simulate.profiles import PROFILES

    gen = WorkloadGenerator(seed=seed)
    reqs = gen.sample_requests(n)
    rng = np.random.RandomState(seed)
    times = GammaArrivals().rate_scaled(1.2).sample_arrival_times(n, rng)
    for r, t in zip(reqs, times):
        r.arrival_time = float(t)
    server = ElisServer(
        FrontendConfig(
            n_nodes=2,
            scheduler=SchedulerConfig(policy="isrtf", batch_size=4,
                                      risk_quantile=risk_quantile),
            preemption=PreemptionConfig(enabled=True),
        ),
        predictor,
        SimExecutor(PROFILES["vic"]),
    )
    for r in reqs:
        server.submit(api.Request.from_workload(r))
    out = server.drain()
    assert all(r.ok for r in out)
    return {r.request_id: (r.jct(), r.n_tokens, r.n_preemptions)
            for r in out}


def test_new_predict_path_trace_identical_to_legacy_scalar_path():
    """With calibration off and risk_quantile=None, the batched
    LengthPredictor path must reproduce the legacy init/iter scoring
    JCT-for-JCT (NoisyOraclePredictor draws RNG per job in scoring order,
    so any reordering diverges immediately)."""
    new = _drain_once(NoisyOraclePredictor(seed=123))
    legacy = _drain_once(LegacyShim(NoisyOraclePredictor(seed=123)))
    assert new == legacy


def test_frontend_feeds_observations_to_calibrator():
    """The serving loop itself (window + finish observations) warms the
    calibrator: after a drain the bias estimate reflects the base's."""
    wrapped = wrap_calibration(
        ScaledOracle(0.5),
        CalibrationConfig(debias=True, ema_alpha=0.3, min_samples=8,
                          by_step=False))
    _drain_once(wrapped)
    assert wrapped.n_observed > 0
    assert wrapped.bias(0) == pytest.approx(0.5, rel=0.25)
    assert not wrapped._pending  # every job reached a terminal observe


def test_per_request_prediction_stats_on_response():
    from repro.core import prediction_stats

    res = _drain_once(OraclePredictor(), n=12)
    assert res  # oracle stats are exercised via the Response surface below
    j = mk_job(0, 100)
    j.pred_trace = [(0, 100.0), (50, 50.0)]
    finish(j)
    mae, bias = prediction_stats(j)
    assert mae == 0.0 and bias == pytest.approx(1.0)
    # unfinished/aborted jobs yield no stats (censored)
    k = mk_job(1, 100)
    k.pred_trace = [(0, 80.0)]
    k.state = JobState.CANCELLED
    assert prediction_stats(k) == (None, None)


def test_predictor_config_encoder_not_shared():
    from repro.core import PredictorConfig

    a, b = PredictorConfig(), PredictorConfig()
    assert a.encoder == b.encoder
    assert a.encoder is not b.encoder  # default_factory, no aliased default
