"""Property tests for batch-formation invariants (hypothesis, shim-safe).

The fused scoring pass (``repro.core.scheduler.score_pool``) rewired how
``ELISFrontend._form_batch`` ranks the pool; these properties pin down what
must survive any such refactor:

* no job is simultaneously in ``waiting`` and ``running``;
* an executed batch never exceeds ``min(batch_size, backend free slots)``
  and never contains duplicates;
* the fused single-pass effective priorities are identical to the old
  two-pass (running, then waiting) values at ``repredict_every=1``;
* exactly one predictor dispatch per scheduling window for a batched
  predictor at ``repredict_every=1``.
"""
from typing import Sequence

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ELISFrontend,
    ExecResult,
    FrontendConfig,
    Job,
    OraclePredictor,
    PreemptionConfig,
    SchedulerConfig,
    make_policy,
)
from repro.core.frontend import Backend
from repro.core.scheduler import batch_effective, score_pool

from _helpers import CountingOracle


class SlottedBackend(Backend):
    """1 s per window, token id 7; tracks residency to enforce slot caps."""

    def __init__(self, slots: int):
        self.slots = slots
        self.resident = {}
        self.calls = []

    def execute(self, node, jobs: Sequence[Job], window, now) -> ExecResult:
        res = self.resident.setdefault(node, set())
        self.calls.append((node, [j.job_id for j in jobs],
                           self.slots - len(res)))
        toks, fin = [], []
        for j in jobs:
            res.add(j.job_id)
            n = min(window, j.true_output_len - j.tokens_generated)
            toks.append([7] * n)
            fin.append(j.tokens_generated + n >= j.true_output_len)
        return ExecResult(1.0, toks, fin)

    def evict(self, node, job):
        self.resident.setdefault(node, set()).discard(job.job_id)

    def capacity(self, node):
        return self.slots

    def free_capacity(self, node):
        return self.slots - len(self.resident.get(node, ()))


def mk_job(i, length, arrival=0.0, klass=0):
    return Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1, 2],
               arrival_time=arrival, true_output_len=length,
               priority_class=klass)


@given(
    lens=st.lists(st.integers(1, 300), min_size=1, max_size=10),
    arrivals=st.lists(st.floats(0.0, 20.0), min_size=1, max_size=10),
    batch=st.integers(1, 5),
    slots=st.integers(1, 6),
    policy=st.sampled_from(["fcfs", "sjf", "isrtf"]),
    preempt=st.booleans(),
    stride=st.integers(1, 4),
)
@settings(max_examples=40, deadline=None)
def test_batch_formation_invariants(lens, arrivals, batch, slots, policy,
                                    preempt, stride):
    backend = SlottedBackend(slots)
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy=policy, window=50,
                                      batch_size=batch,
                                      repredict_every=stride),
            preemption=PreemptionConfig(enabled=preempt, margin=10,
                                        max_fraction=0.5),
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        backend,
    )
    for i, l in enumerate(lens):
        fe.submit(mk_job(i, l, arrival=arrivals[i % len(arrivals)]))
    while fe.pending():
        fe.step()
        for node in fe.running:
            run_ids = {j.job_id for j in fe.running[node]}
            wait_ids = {j.job_id for j in fe.waiting[node]}
            assert not (run_ids & wait_ids), \
                "job simultaneously waiting and running"
    for _, ids, _free_before in backend.calls:
        assert len(ids) <= min(batch, slots)
        assert len(set(ids)) == len(ids), "duplicate job in a batch"
    assert len(fe.finished) == len(lens)
    for j in fe.finished:
        assert j.tokens_generated == j.true_output_len


@given(
    run_lens=st.lists(st.integers(1, 500), min_size=0, max_size=6),
    wait_lens=st.lists(st.integers(1, 500), min_size=0, max_size=6),
    classes=st.lists(st.integers(0, 2), min_size=12, max_size=12),
    aging=st.sampled_from([0.0, 2.5]),
    now=st.floats(1.0, 50.0),
)
@settings(max_examples=60, deadline=None)
def test_fused_pass_matches_two_pass_reference(run_lens, wait_lens, classes,
                                               aging, now):
    """score_pool(full=True) == the pre-fusion two-pass scoring (a
    batch_effective call on running, then one on waiting)."""
    cfg = SchedulerConfig(policy="isrtf", aging_rate=aging)
    pol = make_policy(cfg, OraclePredictor())

    def build():
        jobs = [mk_job(i, l, klass=classes[i % len(classes)])
                for i, l in enumerate(run_lens + wait_lens)]
        for j in jobs:
            j.generated = [7] * (j.true_output_len // 3)
            j.record_enqueue(float(j.job_id % 7))
        return jobs[: len(run_lens)], jobs[len(run_lens):]

    r_ref, w_ref = build()
    ref = (batch_effective(pol, r_ref, now), batch_effective(pol, w_ref, now))
    r_got, w_got = build()
    got = score_pool(pol, r_got, w_got, now, full=True)
    assert got[0] == pytest.approx(ref[0])
    assert got[1] == pytest.approx(ref[1])
    # identical bookkeeping on the jobs themselves
    for a, b in zip(r_ref + w_ref, r_got + w_got):
        assert a.priority == b.priority
        assert a.predictions == b.predictions
        assert a.tokens_at_last_score == b.tokens_at_last_score


@given(
    lens=st.lists(st.integers(1, 250), min_size=1, max_size=8),
    batch=st.integers(1, 4),
)
@settings(max_examples=25, deadline=None)
def test_exactly_one_dispatch_per_window(lens, batch):
    """At repredict_every=1, a batched predictor is dispatched exactly once
    per executed scheduling window (the fused running+waiting pass)."""
    pred = CountingOracle()
    backend = SlottedBackend(slots=8)
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy="isrtf", window=50,
                                      batch_size=batch, repredict_every=1),
            preemption=PreemptionConfig(enabled=True, margin=10,
                                        max_fraction=0.5),
        ),
        pred, backend,
    )
    for i, l in enumerate(lens):
        fe.submit(mk_job(i, l, arrival=0.1 * i))
    fe.run()
    assert pred.dispatches == len(backend.calls)
    assert len(fe.finished) == len(lens)


def test_stride_cuts_dispatches_and_still_finishes():
    """repredict_every=k runs the predictor ~1/k as often on a static pool
    and every job still completes with its exact length."""
    counts = {}
    for stride in (1, 4):
        pred = CountingOracle()
        backend = SlottedBackend(slots=4)
        fe = ELISFrontend(
            FrontendConfig(
                n_nodes=1,
                scheduler=SchedulerConfig(policy="isrtf", window=50,
                                          batch_size=4,
                                          repredict_every=stride),
                preemption=PreemptionConfig(enabled=False),
            ),
            pred, backend,
        )
        for i in range(4):
            fe.submit(mk_job(i, 400))
        done = fe.run()
        assert len(done) == 4
        assert all(j.tokens_generated == 400 for j in done)
        counts[stride] = pred.dispatches
    assert counts[4] < counts[1]
    # 8 windows per job stream at stride 4 -> full scores at windows 0,4,8..
    assert counts[4] <= counts[1] // 2
