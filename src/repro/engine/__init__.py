from repro.engine.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.engine.sampler import SamplerConfig, sample

__all__ = [
    "EngineConfig",
    "EngineExecutor",
    "InferenceEngine",
    "SamplerConfig",
    "sample",
]
