"""Sharded live engine: mesh construction, partition/cache congruence,
tensor-parallel token identity, the mesh-aware Pallas decode kernel (and
its loud fallback for unsupported layouts), and the per-node executor
surface (counters, calibrated fits).

Device-gated tests need forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_engine.py

Under plain tier-1 (one device) they skip; the CI multi-device step runs
them at 8 devices.
"""
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import Job
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine, make_tp_pods
from repro.engine.engine import _batch_axis
from repro.launch.mesh import make_mesh, pod_meshes
from repro.launch.partition import cache_pspecs, sanitize_specs
from repro.models import init_params
from repro.models import transformer as T

needs2 = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >=8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

#: one representative arch per cache family
FAMILY_ARCHS = {
    "dense": "qwen2-1.5b",
    "moe": "qwen2-moe-a2.7b",
    "ssm": "mamba2-130m",
    "hybrid": "zamba2-7b",
    "vlm": "qwen2-vl-7b",
    "audio": "whisper-large-v3",
}


def _mk(i, toks):
    return Job(job_id=i, prompt="x", prompt_tokens=list(toks),
               arrival_time=0.0)


def fake_mesh(shape, names):
    return SimpleNamespace(axis_names=names, devices=np.empty(shape))


# --------------------------------------------------------------------------- #
# Mesh construction
# --------------------------------------------------------------------------- #


def test_make_mesh_validates_shape_axes():
    with pytest.raises(ValueError):
        make_mesh((2, 4), ("model",))


def test_make_mesh_fails_loudly_without_devices():
    with pytest.raises(RuntimeError, match="device"):
        make_mesh((4096,), ("model",))


@needs8
def test_make_mesh_and_pod_meshes_disjoint():
    mesh = make_mesh((2, 4), ("data", "model"))
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
        "data": 2, "model": 4}
    pods = pod_meshes(mesh)
    assert len(pods) == 2
    seen = set()
    for pod in pods:
        ids = {d.id for d in np.asarray(pod.devices).ravel()}
        assert len(ids) == 4
        assert not ids & seen, "pods must own disjoint devices"
        seen |= ids
        assert pod.axis_names == ("model",)


def test_pod_meshes_requires_model_axis():
    with pytest.raises(ValueError, match="model"):
        pod_meshes(fake_mesh((2,), ("data",)))


# --------------------------------------------------------------------------- #
# Partition/cache congruence (every arch family)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("family,arch", sorted(FAMILY_ARCHS.items()))
def test_cache_pspecs_congruent_with_engine_cache(family, arch):
    """partition's cache spec tree must mirror the engine's actual cache
    pytree leaf-for-leaf: same structure, specs within leaf rank, the slot
    (batch) axis replicated, and only head/state axes on "model"."""
    cfg = get_config(arch).reduced()
    assert cfg.family == family
    eng = InferenceEngine(cfg, None, EngineConfig(max_slots=2, max_len=64))
    specs = cache_pspecs(cfg, eng.cache, None, model_size=2,
                         kv_shard="heads")
    spec_td = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    cache_td = jax.tree_util.tree_structure(eng.cache)
    assert spec_td == cache_td, (
        f"{arch}: cache spec tree diverged from the engine cache pytree")
    leaves = jax.tree_util.tree_leaves_with_path(eng.cache)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        bax = _batch_axis(path, leaf.ndim)
        entries = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        assert entries[bax] is None, (
            f"{arch}: slot axis {bax} of {path} must stay replicated, "
            f"got {spec}")
        for ax in entries:
            assert ax in (None, "model"), (path, spec)


@pytest.mark.parametrize("family,arch", sorted(FAMILY_ARCHS.items()))
def test_sanitized_cache_specs_divide_leaf_shapes(family, arch):
    """After sanitize_specs, every sharded axis divides its mesh-axis size
    (what device_put/jit will actually enforce)."""
    cfg = get_config(arch).reduced()
    cache = T.init_cache(cfg, 2, 64)
    mesh = fake_mesh((2,), ("model",))
    specs = sanitize_specs(
        mesh, cache_pspecs(cfg, cache, None, model_size=2,
                           kv_shard="heads"), cache)

    def check(spec, leaf):
        for dim, ax in enumerate(spec):
            if ax is not None:
                assert leaf.shape[dim] % 2 == 0, (spec, leaf.shape)
        return spec

    jax.tree_util.tree_map(check, specs, cache,
                           is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------- #
# Tensor-parallel token identity (the acceptance bar)
# --------------------------------------------------------------------------- #


def _run_identity(arch: str, tp: int):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=4, max_len=128, max_output=64, eos_id=-1)
    ref = InferenceEngine(cfg, params, ecfg)
    mesh = make_mesh((tp,), ("model",))
    sharded = InferenceEngine(cfg, params, ecfg, mesh=mesh)
    prompts = [[11, 22, 33, 44], [9, 8, 7], [301, 302, 303, 304, 305]]
    for eng in (ref, sharded):
        jobs = [_mk(i, p) for i, p in enumerate(prompts)]
        # window 1: two jobs -> compacted decode (gather/scatter sharded)
        t1, _ = eng.run_window(jobs[:2], 6)
        for j, t in zip(jobs, t1):
            j.generated.extend(t)
        # window 2: admit the third job (batched bucketed prefill) and run
        # the full width
        t2, _ = eng.run_window(jobs, 5)
        eng.result = (t1, t2)
    assert ref.result == sharded.result, (
        f"{arch} TP={tp}: sharded tokens diverged from single-device")


@needs2
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen2-moe-a2.7b"])
def test_sharded_token_identity_tp2(arch):
    _run_identity(arch, tp=2)


@needs8
def test_sharded_token_identity_tp4_indivisible_kv():
    """qwen2-1.5b reduced has n_kv_heads=2: TP=4 cannot split the KV head
    axis, so sanitize_specs replicates KV while Q/FFN stay sharded — the
    mixed layout must still be token-identical."""
    _run_identity("qwen2-1.5b", tp=4)


@needs2
def test_preempt_resume_identical_under_sharding():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=1, max_len=128, max_output=64, eos_id=-1)
    mesh = make_mesh((2,), ("model",))
    eng = InferenceEngine(cfg, params, ecfg, mesh=mesh)
    ref = InferenceEngine(cfg, params, ecfg)
    out = {}
    for name, e in (("ref", ref), ("sharded", eng)):
        job = _mk(0, [5, 6, 7])
        t1, _ = e.run_window([job], 5)
        job.generated.extend(t1[0])
        e.evict_job(job.job_id)
        t2, _ = e.run_window([job], 5)   # recompute-resume
        out[name] = t1[0] + t2[0]
    assert out["ref"] == out["sharded"]


@needs2
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen2-moe-a2.7b"])
def test_chunked_prefill_identity_under_tp2(arch):
    """Chunked prefill on a TP pod emits the same greedy tokens as the
    unsharded one-shot engine (the chunk dispatch gathers/scatters the
    sharded slot cache)."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=2, max_len=128, max_output=64, eos_id=-1)
    ref = InferenceEngine(cfg, params, ecfg)
    mesh = make_mesh((2,), ("model",))
    sharded = InferenceEngine(cfg, params, ecfg, mesh=mesh)
    prompt = [11 + k % 60 for k in range(23)]
    out = {}
    for name, eng, chunk in (("ref", ref, None), ("tp2", sharded, 6)):
        job = _mk(0, prompt)
        toks = []
        for _ in range(16):
            t, _ = eng.run_window([job], 4, prefill_chunk=chunk)
            job.generated.extend(t[0])
            toks.extend(t[0])
            if len(toks) >= 8:
                break
        out[name] = toks[:8]
    assert out["ref"] == out["tp2"], \
        f"{arch}: chunked prefill under TP mesh diverged"
    assert sharded.num_chunk_dispatches >= 4


@needs2
def test_swap_roundtrip_bit_exact_under_tp2():
    """offload_job pulls every shard to host (device_get) and restore_job
    re-shards it — the round-trip must be bit-exact under a TP mesh."""
    from repro.engine.engine import _gather_slots
    import jax.numpy as jnp

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=2, max_len=128, max_output=64, eos_id=-1)
    mesh = make_mesh((2,), ("model",))
    eng = InferenceEngine(cfg, params, ecfg, mesh=mesh)
    job = _mk(0, [5, 6, 7, 8])
    t1, _ = eng.run_window([job], 5)
    job.generated.extend(t1[0])
    slot = eng.slot_of[job.job_id]
    before = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([slot], jnp.int32)))
    assert eng.offload_job(job.job_id)
    new_slot = eng.restore_job(job)
    after = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([new_slot], jnp.int32)))
    for a, b in zip(jax.tree_util.tree_leaves(after),
                    jax.tree_util.tree_leaves(before)):
        assert np.array_equal(a, b)
    t2, _ = eng.run_window([job], 5)
    ref = InferenceEngine(cfg, params, ecfg)
    rj = _mk(0, [5, 6, 7, 8])
    r1, _ = ref.run_window([rj], 5)
    rj.generated.extend(r1[0])
    r2, _ = ref.run_window([rj], 5)
    assert t1[0] + t2[0] == r1[0] + r2[0]


# --------------------------------------------------------------------------- #
# Mesh-aware Pallas decode (DESIGN.md §11)
# --------------------------------------------------------------------------- #


@needs2
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen2-moe-a2.7b"])
def test_pallas_token_identity_tp2(arch):
    """The tentpole bar: with a TP2 mesh and head-sharded KV,
    attn_impl='pallas' runs the shard_map'd kernel (no fallback, no
    warning) and emits greedy tokens bit-identical to BOTH the TP XLA
    path and the single-device Pallas path."""
    import warnings as W

    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((2,), ("model",))
    prompts = [[11, 22, 33, 44], [9, 8, 7], [301, 302, 303, 304, 305]]
    outs = {}
    for name, impl, m in (("sd_pallas", "pallas", None),
                          ("tp_xla", "xla", mesh),
                          ("tp_pallas", "pallas", mesh)):
        ecfg = EngineConfig(max_slots=4, max_len=128, max_output=64,
                            eos_id=-1, attn_impl=impl)
        with W.catch_warnings():
            W.simplefilter("error")  # any fallback warning fails the test
            eng = InferenceEngine(cfg, params, ecfg, mesh=m)
        if name == "tp_pallas":
            assert eng.pallas_fallback is False
            assert eng.pallas_fallback_reason is None
            assert eng.cfg.attn_impl == "pallas"
        jobs = [_mk(i, p) for i, p in enumerate(prompts)]
        t1, _ = eng.run_window(jobs[:2], 6)  # compacted decode
        for j, t in zip(jobs, t1):
            j.generated.extend(t)
        t2, _ = eng.run_window(jobs, 5)      # batched admission, full width
        outs[name] = (t1, t2)
    assert outs["tp_pallas"] == outs["tp_xla"], \
        f"{arch}: TP pallas diverged from TP xla"
    assert outs["tp_pallas"] == outs["sd_pallas"], \
        f"{arch}: TP pallas diverged from single-device pallas"


@needs8
def test_pallas_falls_back_with_reason_tp4_indivisible_kv():
    """qwen2-1.5b reduced has n_kv_heads=2: TP=4 cannot split the KV head
    axis (engine_shardings replicates KV), so the per-shard kernel would
    read the wrong local KV head — pallas must fall back, loudly, ONCE,
    and record a 'layout:' reason."""
    import warnings as W

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((4,), ("model",))
    ecfg = EngineConfig(max_slots=2, max_len=64, max_output=16, eos_id=-1,
                        attn_impl="pallas")
    with W.catch_warnings(record=True) as rec:
        W.simplefilter("always")
        eng = InferenceEngine(cfg, params, ecfg, mesh=mesh)
        job = _mk(0, [5, 6, 7])
        eng.run_window([job], 4)
        eng.run_window([job], 4)
    assert eng.pallas_fallback
    assert eng.cfg.attn_impl == "xla"
    assert eng.pallas_fallback_reason.startswith("layout:")
    pallas_warns = [w for w in rec if "pallas" in str(w.message)]
    # the dedupe bugfix: once per ENGINE, not once per dispatch
    assert len(pallas_warns) == 1
    assert "layout:" in str(pallas_warns[0].message)
    # the fallback engine still serves: tokens match the unsharded ref
    ref = InferenceEngine(cfg, params, ecfg)
    rj = _mk(0, [5, 6, 7])
    r1, _ = ref.run_window([rj], 4)
    rj.generated.extend(r1[0])
    r2, _ = ref.run_window([rj], 4)
    assert eng.pallas_fallback  # unchanged by serving


@needs2
def test_pallas_fallback_reason_family_ssm():
    """ssm decode is a recurrent step with no attention read — under a
    mesh pallas falls back with a 'family:' reason (and off-mesh stays
    pallas, where it only affects prefill's ssd_scan)."""
    cfg = get_config("mamba2-130m").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((2,), ("model",))
    with pytest.warns(UserWarning, match="family:"):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(max_slots=2, max_len=64, attn_impl="pallas"),
            mesh=mesh)
    assert eng.pallas_fallback
    assert eng.pallas_fallback_reason.startswith("family:")
    # off-mesh, pallas stays pallas — no warning, no rewrite
    cfg_d = get_config("qwen2-1.5b").reduced()
    params_d = init_params(jax.random.PRNGKey(0), cfg_d)
    eng1 = InferenceEngine(
        cfg_d, params_d, EngineConfig(max_slots=2, max_len=64,
                                      attn_impl="pallas"))
    assert not eng1.pallas_fallback
    assert eng1.pallas_fallback_reason is None
    assert eng1.cfg.attn_impl == "pallas"


@needs2
def test_pallas_support_matrix():
    """pallas_decode_support's reason categories, directly."""
    from repro.launch.partition import pallas_decode_support

    dense = get_config("qwen2-1.5b").reduced()
    tp2 = make_mesh((2,), ("model",))
    assert pallas_decode_support(dense, tp2) is None
    r = pallas_decode_support(dense, fake_mesh((2,), ("data",)))
    assert r.startswith("mesh:")
    r = pallas_decode_support(get_config("mamba2-130m").reduced(), tp2)
    assert r.startswith("family:")
    r = pallas_decode_support(dense, fake_mesh((4,), ("model",)))
    assert r.startswith("layout:")


@needs2
def test_chunked_prefill_identity_under_tp2_pallas():
    """Chunked prefill + TP2 + pallas decode: same greedy tokens as the
    unsharded one-shot XLA engine (chunk attention is always sdpa; the
    pallas kernel serves the decode windows between chunks)."""
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((2,), ("model",))
    ref = InferenceEngine(cfg, params,
                          EngineConfig(max_slots=2, max_len=128,
                                       max_output=64, eos_id=-1))
    sharded = InferenceEngine(
        cfg, params,
        EngineConfig(max_slots=2, max_len=128, max_output=64, eos_id=-1,
                     attn_impl="pallas"), mesh=mesh)
    assert sharded.pallas_fallback is False
    prompt = [11 + k % 60 for k in range(23)]
    out = {}
    for name, eng, chunk in (("ref", ref, None), ("tp2p", sharded, 6)):
        job = _mk(0, prompt)
        toks = []
        for _ in range(16):
            t, _ = eng.run_window([job], 4, prefill_chunk=chunk)
            job.generated.extend(t[0])
            toks.extend(t[0])
            if len(toks) >= 8:
                break
        out[name] = toks[:8]
    assert out["ref"] == out["tp2p"], \
        "chunked prefill under TP pallas diverged"


@needs2
def test_preempt_resume_identical_under_tp2_pallas():
    """Evict + recompute-resume on a TP2 pallas engine matches the
    unsharded XLA reference token-for-token."""
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((2,), ("model",))
    eng = InferenceEngine(
        cfg, params,
        EngineConfig(max_slots=1, max_len=128, max_output=64, eos_id=-1,
                     attn_impl="pallas"), mesh=mesh)
    assert eng.pallas_fallback is False
    ref = InferenceEngine(cfg, params,
                          EngineConfig(max_slots=1, max_len=128,
                                       max_output=64, eos_id=-1))
    out = {}
    for name, e in (("ref", ref), ("tp2p", eng)):
        job = _mk(0, [5, 6, 7])
        t1, _ = e.run_window([job], 5)
        job.generated.extend(t1[0])
        e.evict_job(job.job_id)
        t2, _ = e.run_window([job], 5)   # recompute-resume
        out[name] = t1[0] + t2[0]
    assert out["ref"] == out["tp2p"]


@needs2
def test_shard_map_kernel_matches_single_device_over_len_vectors():
    """Property test on the kernel wrapper itself: for random Q/K/V and
    per-slot kv_len vectors spanning the occupancy range (fresh slot,
    mid-stream, full buffer), the shard_map'd flash_decode is BITWISE
    identical to the single-device kernel."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    mesh = make_mesh((2,), ("model",))
    b, h, kh, d, L = 4, 4, 2, 16, 128
    rng = np.random.default_rng(0)
    len_vectors = [
        [1, 1, 1, 1],                 # every slot fresh
        [1, 37, 77, 128],             # mixed occupancy incl. full buffer
        [128, 128, 128, 128],         # all full
        [5, 5, 64, 3],                # duplicates + short
    ]
    for case, lens in enumerate(len_vectors):
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, L, kh, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, L, kh, d)), jnp.float32)
        kv_len = jnp.asarray(lens, jnp.int32)
        q_off = kv_len - 1
        ref = kops.flash_decode(q, k, v, kv_len=kv_len, q_offset=q_off)
        got = kops.flash_decode(q, k, v, kv_len=kv_len, q_offset=q_off,
                                mesh=mesh)
        assert np.array_equal(np.asarray(ref), np.asarray(got)), \
            f"case {case}: sharded kernel diverged from single-device"
    # indivisible heads must be rejected at the kernel boundary too
    k3 = jnp.zeros((b, L, 3, d), jnp.float32)
    with pytest.raises(ValueError, match="divide"):
        kops.flash_decode(q, k3, k3, kv_len=jnp.ones((b,), jnp.int32),
                          q_offset=jnp.zeros((b,), jnp.int32), mesh=mesh)


@needs8
def test_make_tp_pods_disjoint_and_identical():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=2, max_len=64, max_output=16, eos_id=-1)
    pods = make_tp_pods(cfg, params, ecfg, n_pods=2, tp=2)
    assert sorted(pods) == [0, 1]
    d0 = {d.id for d in np.asarray(pods[0].mesh.devices).ravel()}
    d1 = {d.id for d in np.asarray(pods[1].mesh.devices).ravel()}
    assert d0 and d1 and not d0 & d1
    # data parallelism: both pods serve the same model — identical tokens
    t0, _ = pods[0].run_window([_mk(0, [11, 22, 33])], 6)
    t1, _ = pods[1].run_window([_mk(0, [11, 22, 33])], 6)
    assert t0 == t1
    # over-ask relative to however many devices this process actually has
    # (the full test suite may run with dryrun's 512 forced host devices)
    too_many = len(jax.devices()) // 2 + 1
    with pytest.raises(RuntimeError, match="devices"):
        make_tp_pods(cfg, params, ecfg, n_pods=too_many, tp=2)


# --------------------------------------------------------------------------- #
# Per-node executor surface (runs on one device)
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def two_node_executor():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=2, max_len=64, max_output=64, eos_id=-1)
    ex = EngineExecutor({0: InferenceEngine(cfg, params, ecfg),
                         1: InferenceEngine(cfg, params, ecfg)})
    jid = [0]

    def go(node, batch, window):
        jobs = [_mk(1000 + jid[0] + i, [3, 4, 5]) for i in range(batch)]
        jid[0] += batch
        ex.execute(node, jobs, window, now=0.0)
        for j in jobs:
            ex.evict(node, j)

    # node 0 sees more traffic than node 1, at two (batch, window) shapes
    for _ in range(3):
        go(0, 1, 2)
        go(0, 2, 4)
    go(1, 1, 2)
    go(1, 1, 4)
    return ex


def test_node_counters_separable(two_node_executor):
    ex = two_node_executor
    per = ex.node_counters()
    assert sorted(per) == [0, 1]
    assert per[0]["windows_executed"] == 6
    assert per[1]["windows_executed"] == 2
    # a storm on one pod is attributable: node 0 compiled two decode
    # shapes, node 1 two of its own
    for n in (0, 1):
        assert per[n]["decode_traces"] >= 1
        assert per[n]["decode_dispatches"] == per[n]["windows_executed"]
    agg = ex.counters()
    for k in ("prefill_traces", "prefill_dispatches", "decode_traces",
              "decode_dispatches", "windows_executed"):
        assert agg[k] == per[0][k] + per[1][k], k


def test_per_node_calibrated_profiles(two_node_executor):
    ex = two_node_executor
    profs = ex.calibrated_node_profiles()
    assert sorted(profs) == [0, 1]
    for n, p in profs.items():
        assert p.name == f"live-node{n}"
        assert p.decode_ms_1 > 0
    assert sorted(ex.node_fit_overhead_s) == [0, 1]
    costs = ex.node_token_cost()
    assert all(c > 0 for c in costs.values())
    # node filtering really filters: fitting node 0 alone must equal the
    # profile from a log containing only node-0 windows
    only0 = EngineExecutor(ex.engines)
    only0.window_log = [r for r in ex.window_log if r["node"] == 0]
    a = ex.calibrated_profile(nodes=[0])
    b = only0.calibrated_profile()
    assert np.isclose(a.avg_latency_ms, b.avg_latency_ms)
    assert np.isclose(a.batch_slowdown, b.batch_slowdown)
