"""Scheduling-critical-path overhead: per-window scoring cost vs pool size.

ELIS's ISRTF re-scores every live job each 50-token window (Algorithm 1
lines 11–14), so predictor latency sits directly on the scheduling critical
path.  This benchmark measures, for FCFS / SJF / ISRTF over growing pools:

* wall time spent forming each scheduling window's batch (``_form_batch``);
* predictor dispatches per window — the fused running+waiting pass makes
  this exactly 1 for ISRTF at ``repredict_every=1``, and ~1/k at stride k;
* ``BGEPredictor.num_traces`` — with shape-bucketed inference the jitted
  apply compiles once per (batch, seq) bucket, NOT once per pool size, so
  the trace count stays <= the bucket bound however the pool grows
  (the recompile-storm guard, asserted in ``--smoke`` by CI).

Emits ``BENCH_sched_overhead.json`` at the repo root (committed) plus the
usual ``experiments/results`` copy.

    PYTHONPATH=src python -m benchmarks.scheduler_overhead [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core import (
    BGEPredictor,
    ELISFrontend,
    ExecResult,
    FrontendConfig,
    Job,
    PredictorConfig,
    PreemptionConfig,
    SchedulerConfig,
)
from repro.data import n_shape_buckets
from repro.models.encoder import EncoderArchConfig

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sched_overhead.json")


class ReplayBackend:
    """Deterministic backend: each window takes 1 virtual second and
    replays token id 7 — execution is free, so step wall-time ~= scheduling
    cost."""

    def __init__(self):
        self.calls = 0

    def execute(self, node, jobs: Sequence[Job], window, now) -> ExecResult:
        self.calls += 1
        toks, fin = [], []
        for j in jobs:
            n = min(window, j.true_output_len - j.tokens_generated)
            toks.append([7] * n)
            fin.append(j.tokens_generated + n >= j.true_output_len)
        return ExecResult(1.0, toks, fin)

    def evict(self, node, job):
        pass


def tiny_predictor(seed: int = 0) -> BGEPredictor:
    cfg = PredictorConfig(
        encoder=EncoderArchConfig(d_model=64, n_heads=2, n_layers=2,
                                  d_ff=128, max_len=128),
        n_fc_layers=4, fc_hidden=64, max_len=128,
    )
    return BGEPredictor(cfg, seed=seed)


def one_run(policy: str, pool: int, repredict_every: int = 1,
            seed: int = 0) -> Dict:
    """Serve ``pool`` staggered jobs to completion; time every non-empty
    batch formation."""
    rng = np.random.RandomState(seed)
    predictor = None if policy == "fcfs" else tiny_predictor(seed)
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy=policy, window=50, batch_size=4,
                                      repredict_every=repredict_every),
            preemption=PreemptionConfig(enabled=policy == "isrtf",
                                        margin=50.0, max_fraction=0.25),
        ),
        predictor,
        ReplayBackend(),
    )
    for i in range(pool):
        # staggered arrivals grow the live pool one job at a time — the
        # exact access pattern that used to retrace XLA per pool size
        fe.submit(Job(
            job_id=i, prompt=f"p{i}",
            prompt_tokens=[int(t) for t in
                           rng.randint(1, 8000, rng.randint(4, 60))],
            arrival_time=0.31 * i,
            true_output_len=int(rng.choice([60, 150, 400])),
        ))

    times: List[float] = []
    orig = fe._form_batch

    def timed(node, now, out):
        t0 = time.perf_counter()
        batch = orig(node, now, out)
        if batch:
            times.append(time.perf_counter() - t0)
        return batch

    fe._form_batch = timed
    done = fe.run()
    assert len(done) == pool, f"{policy}: {len(done)}/{pool} finished"

    ms = np.array(times) * 1e3
    row = {
        "policy": policy,
        "repredict_every": repredict_every,
        "pool": pool,
        "windows": len(times),
        "sched_ms_mean": round(float(ms.mean()), 3),
        "sched_ms_p50": round(float(np.median(ms)), 3),
        "sched_ms_max": round(float(ms.max()), 3),
    }
    if predictor is not None and hasattr(predictor, "num_dispatches"):
        bound = n_shape_buckets(pool, predictor.cfg.max_len)
        row.update({
            "dispatches": predictor.num_dispatches,
            "dispatches_per_window": round(
                predictor.num_dispatches / max(len(times), 1), 3),
            "num_traces": predictor.num_traces,
            "trace_bound": bound,
        })
    return row


def run(quick: bool = False, smoke: bool = False) -> List[Dict]:
    pools = [2, 4, 8] if smoke else ([4, 8, 16] if quick else [4, 8, 16, 32])
    rows: List[Dict] = []
    for policy in ("fcfs", "sjf", "isrtf"):
        for pool in pools:
            rows.append(one_run(policy, pool))
    # the staleness knob: same ISRTF workload, encoder every 4th window
    for pool in pools[-2:]:
        rows.append(one_run("isrtf", pool, repredict_every=4))

    # hard guarantees the JSON is committed to document
    for r in rows:
        if r["policy"] == "isrtf" and r["repredict_every"] == 1:
            assert r["dispatches"] == r["windows"], (
                "fused pass must make exactly one predictor dispatch per "
                f"scheduling window, got {r}")
        if "num_traces" in r:
            assert r["num_traces"] <= r["trace_bound"], (
                f"recompile storm: {r['num_traces']} traces > bucket bound "
                f"{r['trace_bound']}: {r}")
    strided = [r for r in rows if r["repredict_every"] == 4]
    for r in strided:
        full = next(x for x in rows if x["policy"] == "isrtf"
                    and x["repredict_every"] == 1 and x["pool"] == r["pool"])
        assert r["dispatches"] < full["dispatches"], (
            "repredict_every=4 must dispatch the predictor less often "
            f"than every window: {r} vs {full}")

    save_results("scheduler_overhead", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools, assertions only (CI recompile guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    if not args.smoke:
        # regenerate the committed evidence only on a deliberate CLI run
        # (--smoke and programmatic benchmarks.run invocations must not
        # clobber it with reduced-pool rows)
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    for r in rows:
        print(r)
    isrtf = [r for r in rows
             if r["policy"] == "isrtf" and r["repredict_every"] == 1]
    print(f"[scheduler_overhead] isrtf traces "
          f"{max(r['num_traces'] for r in isrtf)} <= bound "
          f"{max(r['trace_bound'] for r in isrtf)}; "
          f"one dispatch/window: "
          f"{all(r['dispatches'] == r['windows'] for r in isrtf)}")


if __name__ == "__main__":
    main()
