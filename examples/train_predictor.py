"""Train the response-length predictor (paper §4.2) and checkpoint it.

    PYTHONPATH=src python examples/train_predictor.py [--steps 600]

Reports Table-2-style metrics (MAE/RMSE/R²) before and after training plus
the Fig-2(b) per-step MAE curve, and saves a msgpack/npz checkpoint.
"""
import argparse
import os

from repro.core import BGEPredictor, PredictorConfig
from repro.data import make_predictor_dataset
from repro.models.encoder import EncoderArchConfig
from repro.training import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--requests", type=int, default=1200)
    ap.add_argument("--out", default="experiments/predictor_ckpt")
    args = ap.parse_args()

    cfg = PredictorConfig(
        encoder=EncoderArchConfig(d_model=128, n_heads=4, n_layers=3,
                                  d_ff=256, max_len=192),
        n_fc_layers=8, fc_hidden=256, max_len=192, lr=1e-4,
    )
    train, val, test = make_predictor_dataset(args.requests, seed=0,
                                              max_len=192, max_steps=6)
    print(f"dataset: {len(train)} train / {len(val)} val / {len(test)} test")

    pred = BGEPredictor(cfg, seed=0)
    print("before:", pred.evaluate(test))
    pred.fit(train, num_steps=args.steps, batch_size=32,
             log_fn=lambda i, m: print(f"  step {i:4d} loss={m['loss']:.4f} "
                                       f"mae={m['mae']:.1f}"))
    after = pred.evaluate(test)
    print("after:", after)
    print("per-step MAE (Fig 2b):", pred.evaluate_per_step(test))

    os.makedirs(args.out, exist_ok=True)
    path = save_checkpoint(args.out, args.steps, pred.params,
                           metadata={"metrics": after})
    print("checkpoint:", path)


if __name__ == "__main__":
    main()
