"""JAX inference engine — the vLLM analogue the ELIS backend workers drive.

TPU-idiomatic design (see DESIGN.md §3): instead of paged KV blocks, a
fixed-capacity **slot-based** cache — every decode slot owns a contiguous
KV/state region of a statically-shaped batched cache, and slots advance
independently (per-slot ``len`` vector).  Slot recycling replaces page
allocation; preemption = slot eviction + recompute-on-resume.

The two features the paper adds to vLLM are first-class here:
  * **iteration-wise execution** — ``run_window`` executes exactly K tokens
    (or to EOS) for the scheduled batch and returns partial outputs;
  * **configurable priorities** — the scheduler decides which jobs hold
    slots each window; ``evict``/``add`` implement priority preemption.

Fast path (DESIGN.md §3.2–§3.4):
  * **batched bucketed prefill** — ``add_jobs`` admits every newly scheduled
    job in ONE padded ``(batch_bucket, seq_bucket)`` prefill dispatch per
    window instead of N batch-1 calls; the shape-bucket ladder is the same
    one ``BGEPredictor`` uses (``repro.data.dataset``), so the jitted
    prefill compiles once per bucket no matter how admissions arrive.
    Attention families right-pad prompts to the bucket (causality + the
    kv_len mask make pads harmless); SSM/hybrid families keep exact-length
    batch-1 prefill because recurrent state would absorb pad positions.
  * **masked decode windows** — each decode dispatch carries a per-slot
    ``active`` mask (occupied ∧ not-EOS).  When occupancy is below capacity
    the engine *compacts*: it gathers the scheduled slots into a
    ``batch_bucket``-sized sub-cache, decodes only those rows, and scatters
    back —
    empty slots stop burning FLOPs.  Within the window, a slot that emits
    EOS is *frozen* for the remaining ``lax.scan`` steps: no KV/state
    write, no ``len`` advance, PAD emissions (see ``T.decode_step``).
  * **Pallas decode attention** — ``attn_impl="pallas"`` routes
    ``T.decode_step`` through :mod:`repro.kernels.decode_attention` with
    the per-slot ``len`` vector as kv lengths; ``"xla"`` stays the
    reference path (numerics-equivalence is CI-guarded).  Under a TP mesh
    the kernel runs ``shard_map``-ped over the "model" axis when the head
    layout supports it (DESIGN.md §11, docs/kernels.md); unsupported
    layouts fall back loudly, once, with the reason.
  * **compile/dispatch counters** — ``num_prefill_traces`` /
    ``num_prefill_dispatches`` / ``num_decode_traces`` /
    ``num_decode_dispatches`` mirror ``BGEPredictor``'s recompile-storm
    hooks; ``EngineExecutor.counters()`` aggregates them and
    ``EngineExecutor.calibrated_profile()`` fits the measured window
    durations back onto the simulator's latency model (live↔sim
    calibration).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontend import Backend, ExecResult
from repro.core.job import Job
from repro.data.dataset import batch_bucket, n_shape_buckets, seq_bucket
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.engine.sampler import SamplerConfig, sample
from repro.models import transformer as T

#: recurrent-state families prefill at exact length (pad positions would be
#: absorbed into the state), so they keep serial batch-1 admission
EXACT_PREFILL_FAMILIES = ("ssm", "hybrid")


@dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_len: int = 512
    max_output: int = 1024
    eos_id: int = EOS_ID
    #: smallest prefill sequence bucket; padded lengths follow the
    #: power-of-two ``repro.data.seq_bucket`` ladder up to ``max_len``
    prefill_bucket: int = 16
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    #: decode attention implementation: "xla" (einsum reference) or
    #: "pallas" (flash-decode kernel over the slot cache)
    attn_impl: str = "xla"
    #: admit all newly scheduled jobs in one padded (batch, seq)-bucketed
    #: prefill dispatch (False = one batch-1 dispatch per job, the
    #: pre-fast-path baseline kept for benchmarking)
    batched_prefill: bool = True
    #: compact decode dispatches to the batch bucket of the *scheduled*
    #: slots and freeze unscheduled/EOS slots (False = always decode the
    #: full ``max_slots`` batch, the pre-fast-path baseline)
    masked_decode: bool = True
    #: honour each request's own token budget (job.true_output_len acts as
    #: the request's ``max_tokens``, like vLLM's per-request cap)
    respect_job_max: bool = False


# --------------------------------------------------------------------------- #
# Slot-cache gather/scatter
# --------------------------------------------------------------------------- #


def _batch_axis(path, ndim: int) -> int:
    """Slot (batch) axis of a cache leaf.

    Convention (see T.init_cache): 1-D leaves are the per-slot ``len``
    vector; stacked KV/state leaves carry a leading layer/site axis with
    batch at axis 1 — except the hybrid family's ``groups_ssm``, whose
    states are stacked (n_groups, inner, batch, ...).
    """
    if ndim == 1:
        return 0
    top = getattr(path[0], "key", None)
    return 2 if top == "groups_ssm" else 1


def _gather_slots(cache, idx: jnp.ndarray):
    """Gather slot rows ``idx`` of the cache pytree into a sub-cache."""

    def take(path, leaf):
        return jnp.take(leaf, idx, axis=_batch_axis(path, leaf.ndim))

    return jax.tree_util.tree_map_with_path(take, cache)


def _scatter_slots(big, small, slots: Sequence[int], n: int):
    """Write rows ``0..n-1`` of the batched ``small`` cache pytree into the
    given ``slots`` of ``big`` (rows beyond ``n`` are bucket padding)."""
    sl = jnp.asarray(list(slots)[:n], jnp.int32)

    def put(path, b, s):
        ax = _batch_axis(path, b.ndim)
        if ax == 0:
            return b.at[sl].set(s[:n])
        if ax == 1:
            return b.at[:, sl].set(s[:, :n])
        return b.at[:, :, sl].set(s[:, :, :n])

    return jax.tree_util.tree_map_with_path(put, big, small)


class InferenceEngine:
    """One backend worker's execution engine (one model, N slots).

    With ``mesh`` (a single-axis ``("model",)`` jax Mesh — one TP pod),
    parameters and the slot cache are sharded via ``repro.launch.partition``
    (heads/ffn/vocab on the "model" axis, slots replicated) and every
    prefill/decode dispatch is jitted with ``NamedSharding``-annotated
    inputs/outputs, so XLA inserts the tensor-parallel collectives.

    ``attn_impl="pallas"`` under a mesh runs the **mesh-aware** flash-decode
    kernel (``shard_map`` over "model", each shard attending its local KV
    heads — DESIGN.md §11, docs/kernels.md) whenever
    ``launch.partition.pallas_decode_support`` reports the layout supported;
    otherwise the engine warns **once**, with the reason, and falls back to
    the XLA decode path (``pallas_fallback`` / ``pallas_fallback_reason``).
    Prefill-side kernels stay single-device, so under a mesh prefill always
    uses the XLA path (identical numerics; ``T.prefill`` downgrades
    internally)."""

    def __init__(self, model_cfg, params, cfg: Optional[EngineConfig] = None,
                 mesh=None):
        if cfg is None:
            cfg = EngineConfig()
        self.pallas_fallback = False
        #: why pallas fell back (None when it didn't): a reason string from
        #: ``launch.partition.pallas_decode_support``, category-prefixed
        #: ("mesh:" / "family:" / "layout:")
        self.pallas_fallback_reason: Optional[str] = None
        self.mesh = mesh
        self._warned: set = set()
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"engine mesh needs a 'model' axis, got {mesh.axis_names}")
            if cfg.attn_impl == "pallas":
                from repro.launch.partition import pallas_decode_support
                reason = pallas_decode_support(model_cfg, mesh)
                if reason is not None:
                    # the loud-fallback rule: never silently serve different
                    # numerics — but only for layouts the shard_map'd kernel
                    # genuinely cannot cover (DESIGN.md §11)
                    self._warn_once(
                        "pallas_fallback",
                        "attn_impl='pallas' cannot shard for this "
                        f"(config, mesh) — {reason}; falling back to the "
                        "XLA decode-attention path")
                    cfg = dataclasses.replace(cfg, attn_impl="xla")
                    self.pallas_fallback = True
                    self.pallas_fallback_reason = reason
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.cache = T.init_cache(model_cfg, cfg.max_slots, cfg.max_len)
        if mesh is None:
            self.params = params
            self._param_sh = self._cache_sh = self._repl = None
        else:
            from repro.launch.partition import engine_shardings
            self._param_sh, self._cache_sh, self._repl = engine_shardings(
                mesh, model_cfg, params, self.cache)
            # one host copy of params serves any number of pods: each engine
            # device_puts onto its own (disjoint) mesh
            self.params = jax.device_put(params, self._param_sh)
            self.cache = jax.device_put(self.cache, self._cache_sh)
        self.slot_job: List[Optional[int]] = [None] * cfg.max_slots
        self.slot_of: Dict[int, int] = {}
        self.last_token = np.full((cfg.max_slots, 1), PAD_ID, np.int32)
        self._key = jax.random.PRNGKey(0)

        #: compile/dispatch introspection (mirrors BGEPredictor's hooks):
        #: traces increment via a Python side effect that runs only while
        #: JAX traces a new input shape, so they count compiled shape
        #: buckets, not calls
        self.num_prefill_dispatches = 0
        self.num_decode_dispatches = 0
        self._prefill_traces = 0
        self._decode_traces = 0

        mc, ec = model_cfg, cfg

        def _prefill_fn(params, tokens, cache1, last_index):
            self._prefill_traces += 1  # side effect: once per shape bucket
            batch = {"tokens": tokens}
            return T.prefill(params, mc, batch, cache1,
                             attn_impl=ec.attn_impl, last_index=last_index,
                             mesh=mesh)

        if mesh is None:
            self._prefill = jax.jit(_prefill_fn)
        else:
            # NamedSharding-annotated in/out: params arrive TP-sharded, the
            # batched sub-cache replicates slots but shards heads/state, and
            # XLA inserts the all-reduces (wo / w_down partial sums)
            self._prefill = jax.jit(
                _prefill_fn,
                in_shardings=(self._param_sh, self._repl, self._cache_sh,
                              self._repl),
                out_shardings=(self._repl, self._cache_sh))
        self._window_cache: Dict[Tuple[int, int], object] = {}
        #: first generated token (sampled from prefill logits), pending emission
        self._pending_first: Dict[int, int] = {}

        # ---- chunked prefill state (run_window(prefill_chunk=...)) ----
        #: jitted chunk dispatches, one per padded chunk length
        self._chunk_cache: Dict[int, object] = {}
        #: job_id -> tokens already span-written into its slot's cache
        self._prefill_cursor: Dict[int, int] = {}
        #: job_id -> total tokens to prefill (prompt, or resume context)
        self._chunk_target: Dict[int, int] = {}
        #: job_id -> the full token stream being chunk-prefilled
        self._chunk_tokens: Dict[int, List[int]] = {}
        #: job_id -> True when the chunked prefill re-establishes a resumed
        #: job's context (counts toward ``resume_context_tokens``)
        self._chunk_resumed: Dict[int, bool] = {}
        self.num_chunk_dispatches = 0
        self._chunk_traces = 0

        # ---- KV offload tier (offload_job/restore_job) ----
        #: job_id -> host-memory copy of the slot cache + decode bookkeeping
        self._host_stash: Dict[int, Dict] = {}
        #: watermark (stashed context tokens) bounding the host swap pool;
        #: None = unbounded.  ``EngineExecutor`` threads
        #: ``PreemptionConfig.swap_pool_tokens`` here; over-watermark
        #: swap-outs evict the COLDEST stashed victims to the
        #: recompute-fallback path (loud, once per engine)
        self.swap_pool_tokens: Optional[int] = None
        #: context tokens currently held in the host stash
        self.stash_tokens = 0
        #: stashes evicted by the watermark (victims fell back to recompute)
        self.n_stash_evictions = 0
        self.stash_evicted_tokens = 0

        #: tokens of context re-established by resume prefills (full or
        #: chunked), INCLUDING the +1 seed token whose KV is written by the
        #: first decode step — the live counterpart of the simulator's
        #: recompute charge (``SimExecutor.recompute_prefill_tokens``)
        self.resume_context_tokens = 0

    # ------------------------------------------------------------------ #
    def _warn_once(self, key: str, msg: str) -> None:
        """Emit a ``UserWarning`` at most ONCE per engine per ``key`` — the
        shared guard behind every loud-fallback site (pallas-under-mesh,
        unsupported chunked prefill).  Per-dispatch repetition would bury
        the reason; the message always carries it."""
        if key in self._warned:
            return
        self._warned.add(key)
        warnings.warn(msg, UserWarning, stacklevel=3)

    # ------------------------------------------------------------------ #
    def _canon_cache(self, cache):
        """Pin a cache pytree to the canonical NamedShardings (mesh mode).

        The slot gather/scatter runs eagerly between jitted dispatches, and
        its outputs inherit whatever layout GSPMD propagated; an explicit
        ``device_put`` keeps the persistent cache (and gathered sub-caches)
        exactly on the contract the annotated jits expect.  No-op off-mesh
        and free when the sharding already matches."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, self._cache_sh)

    # ------------------------------------------------------------------ #
    @property
    def num_prefill_traces(self) -> int:
        return self._prefill_traces

    @property
    def num_decode_traces(self) -> int:
        return self._decode_traces

    def prefill_shape_bound(self) -> int:
        """Upper bound on distinct prefill shapes the bucketing can emit
        (attention families; exact-length families are unbounded by
        design).  The CI smoke guard asserts ``num_prefill_traces`` stays
        under this no matter how admissions arrive."""
        return n_shape_buckets(self.cfg.max_slots, self.cfg.max_len,
                               self.cfg.prefill_bucket)

    def decode_batch_buckets(self) -> int:
        """Distinct decode batch sizes compaction can dispatch."""
        return len({min(batch_bucket(n), self.cfg.max_slots)
                    for n in range(1, self.cfg.max_slots + 1)})

    # ------------------------------------------------------------------ #
    # Chunked prefill
    # ------------------------------------------------------------------ #

    @property
    def num_chunk_traces(self) -> int:
        return self._chunk_traces

    def chunk_supported(self) -> bool:
        """Chunked prefill needs a position-addressable dense KV cache:
        attention families only (recurrent state absorbs pads), no ring/SWA
        buffer (span writes are position-destructive there), no int8 KV
        (the chunk would attend a dequantized prefix while one-shot prefill
        attends the fresh unquantized K/V)."""
        if self.model_cfg.family not in T.CHUNKABLE_FAMILIES:
            return False
        kvc = self.cache.get("kv")
        return kvc is not None and not kvc.ring and not kvc.quantized

    def _chunk_fn(self, padded_len: int):
        """jit per padded chunk length (start/valid stay traced, so the
        whole prefill ladder reuses these few shapes)."""
        if padded_len not in self._chunk_cache:
            mc, ec = self.model_cfg, self.cfg

            def fn(params, tokens, cache1, start, valid):
                self._chunk_traces += 1  # side effect: once per shape
                return T.prefill_chunk(params, mc, {"tokens": tokens}, cache1,
                                       attn_impl=ec.attn_impl,
                                       start=start, valid_len=valid)

            if self.mesh is None:
                self._chunk_cache[padded_len] = jax.jit(fn)
            else:
                self._chunk_cache[padded_len] = jax.jit(
                    fn,
                    in_shardings=(self._param_sh, self._repl, self._cache_sh,
                                  self._repl, self._repl),
                    out_shardings=(self._repl, self._cache_sh))
        return self._chunk_cache[padded_len]

    def _alloc_slot(self, job: Job) -> int:
        """Claim a slot WITHOUT prefilling (chunked admission): the slot's
        ``len`` is zeroed and the prompt is span-written chunk by chunk
        across subsequent windows (stale K/V from a previous occupant is
        dead weight behind the kv_len mask, exactly as after a one-shot
        scatter)."""
        free = [s for s, owner in enumerate(self.slot_job) if owner is None]
        if not free:
            raise RuntimeError("no free slot to allocate")
        slot = free[0]
        toks = self._resume_tokens(job)
        if len(toks) > self.cfg.max_len:
            raise ValueError(
                f"prompt of {len(toks)} tokens exceeds max_len="
                f"{self.cfg.max_len}")
        self.slot_job[slot] = job.job_id
        self.slot_of[job.job_id] = slot
        self.last_token[slot, 0] = PAD_ID
        self._prefill_cursor[job.job_id] = 0
        self._chunk_target[job.job_id] = len(toks)
        self._chunk_tokens[job.job_id] = toks
        self._chunk_resumed[job.job_id] = bool(job.generated)
        lens = np.asarray(self.cache["len"]).copy()
        lens[slot] = 0
        self.cache["len"] = jnp.asarray(lens)
        return slot

    def prefill_incomplete(self, job_id: int) -> bool:
        """True while a chunk-admitted job still has prompt tokens to
        ingest — such a job is excluded from decode dispatches."""
        cur = self._prefill_cursor.get(job_id)
        return cur is not None and cur < self._chunk_target[job_id]

    def _run_chunk(self, job: Job, chunk: int) -> None:
        """Ingest the next (at most) ``chunk`` prompt tokens of ``job`` in
        one batch-1 dispatch against its slot's partially-filled cache."""
        jid = job.job_id
        toks_all = self._chunk_tokens[jid]
        cur = self._prefill_cursor[jid]
        target = self._chunk_target[jid]
        n = min(chunk, target - cur)
        padded = seq_bucket(n, self.cfg.max_len,
                            min_bucket=self.cfg.prefill_bucket)
        toks = np.full((1, padded), PAD_ID, np.int32)
        toks[0, :n] = toks_all[cur:cur + n]
        slot = self.slot_of[jid]
        sub = self._canon_cache(
            _gather_slots(self.cache, jnp.asarray([slot], jnp.int32)))
        self.num_chunk_dispatches += 1
        logits, sub = self._chunk_fn(padded)(
            self.params, jnp.asarray(toks), sub,
            jnp.asarray([cur], jnp.int32), jnp.asarray([n], jnp.int32))
        self.cache = self._canon_cache(
            _scatter_slots(self.cache, sub, [slot], 1))
        self._prefill_cursor[jid] = cur + n
        if self._chunk_resumed[jid]:
            self.resume_context_tokens += n
        if cur + n >= target:
            # prefill complete: seed decode exactly like one-shot admission
            if job.generated:
                self.last_token[slot, 0] = job.generated[-1]
                self.resume_context_tokens += 1  # the seed token's KV write
            else:
                first = int(np.argmax(np.asarray(logits)[0, -1]))
                self._pending_first[jid] = first
                self.last_token[slot, 0] = first

    # ------------------------------------------------------------------ #
    # KV offload tier
    # ------------------------------------------------------------------ #

    def offload_job(self, job_id: int) -> bool:
        """Evict a job's slot but keep its KV/state in HOST memory — resume
        swaps it back in instead of paying recompute.  ``jax.device_get``
        pulls every shard to host under a mesh; the stash also carries the
        decode bookkeeping (last token, pending first emission, chunk
        cursor) so a restored job continues bit-exactly.

        With ``swap_pool_tokens`` set, the host stash is bounded: an
        over-watermark swap-out evicts the COLDEST stashed victims (oldest
        swap-outs, insertion order) to the recompute-fallback path; if the
        fresh stash alone exceeds the pool it is refused (returns False, the
        caller falls back to plain eviction + recompute)."""
        slot = self.slot_of.get(job_id)
        if slot is None:
            return False
        ctx = int(np.asarray(self.cache["len"])[slot])
        sub = _gather_slots(self.cache, jnp.asarray([slot], jnp.int32))
        self._host_stash[job_id] = {
            "cache": jax.device_get(sub),
            "last": int(self.last_token[slot, 0]),
            "pending": self._pending_first.get(job_id),
            "cursor": self._prefill_cursor.get(job_id),
            "target": self._chunk_target.get(job_id),
            "tokens": self._chunk_tokens.get(job_id),
            "resumed": self._chunk_resumed.get(job_id),
            "ctx": ctx,
        }
        self.stash_tokens += ctx
        if self.swap_pool_tokens is not None:
            # evict coldest-first until under the watermark; the fresh
            # stash (newest) is only dropped when it alone exceeds the pool
            while (self.stash_tokens > self.swap_pool_tokens
                   and len(self._host_stash) > 1):
                self._evict_coldest_stash()
            if self.stash_tokens > self.swap_pool_tokens:
                self._evict_coldest_stash()  # the fresh stash itself
        self.evict_job(job_id)
        return job_id in self._host_stash

    def _evict_coldest_stash(self) -> None:
        """Watermark eviction: drop the oldest stash (coldest victim) —
        that job resumes through the recompute-fallback path."""
        victim, st = next(iter(self._host_stash.items()))
        del self._host_stash[victim]
        ctx = st.get("ctx", 0)
        self.stash_tokens -= ctx
        self.n_stash_evictions += 1
        self.stash_evicted_tokens += ctx
        self._warn_once(
            "swap_pool_evict",
            f"host KV swap pool exceeded its {self.swap_pool_tokens}-token "
            f"watermark (PreemptionConfig.swap_pool_tokens); evicting the "
            f"coldest stashed victims to recompute-fallback — raise the "
            f"watermark or reduce preemption pressure if swap-ins were "
            f"expected to stay warm")

    def restore_job(self, job: Job) -> int:
        """Swap a host-stashed job back into a free slot, bit-exactly."""
        st = self._host_stash.pop(job.job_id)
        self.stash_tokens -= st.get("ctx", 0)
        free = [s for s, owner in enumerate(self.slot_job) if owner is None]
        if not free:
            raise RuntimeError("no free slot to restore into")
        slot = free[0]
        sub = jax.device_put(st["cache"])
        if self.mesh is not None:
            sub = self._canon_cache(sub)
        self.cache = self._canon_cache(
            _scatter_slots(self.cache, sub, [slot], 1))
        self.slot_job[slot] = job.job_id
        self.slot_of[job.job_id] = slot
        self.last_token[slot, 0] = st["last"]
        if st["pending"] is not None:
            self._pending_first[job.job_id] = st["pending"]
        if st["cursor"] is not None:
            self._prefill_cursor[job.job_id] = st["cursor"]
            self._chunk_target[job.job_id] = st["target"]
            self._chunk_tokens[job.job_id] = st["tokens"]
            self._chunk_resumed[job.job_id] = st["resumed"]
        return slot

    def has_stash(self, job_id: int) -> bool:
        return job_id in self._host_stash

    def drop_stash(self, job_id: int) -> None:
        """Release a job's host-memory KV copy (terminal states, or a
        migration that abandons the cache)."""
        st = self._host_stash.pop(job_id, None)
        if st is not None:
            self.stash_tokens -= st.get("ctx", 0)

    # ------------------------------------------------------------------ #
    def _decode_window(self, window: int, batch: int):
        """jit per (window length, compacted batch size) — both static."""
        key2 = (window, batch)
        if key2 not in self._window_cache:
            mc, ec = self.model_cfg, self.cfg

            def fn(params, cache, last_tokens, alive, rng):
                self._decode_traces += 1  # side effect: once per shape

                def step(carry, _):
                    cache, toks, alive, rng = carry
                    logits, cache = T.decode_step(params, mc, toks, cache,
                                                  attn_impl=ec.attn_impl,
                                                  active=alive,
                                                  mesh=self.mesh)
                    rng, sub = jax.random.split(rng)
                    nxt = sample(logits[:, -1, :], sub, ec.sampler,
                                 active=alive, pad_token=PAD_ID)[:, None]
                    # EOS freezes the slot for the rest of the scan: no
                    # KV/state write, no len advance, PAD emissions
                    alive = alive & (nxt[:, 0] != ec.eos_id)
                    return (cache, nxt, alive, rng), nxt[:, 0]

                (cache, _, _, _), toks = jax.lax.scan(
                    step, (cache, last_tokens, alive, rng), None,
                    length=window
                )
                return cache, jnp.swapaxes(toks, 0, 1)

            if self.mesh is None:
                self._window_cache[key2] = jax.jit(fn)
            else:
                self._window_cache[key2] = jax.jit(
                    fn,
                    in_shardings=(self._param_sh, self._cache_sh, self._repl,
                                  self._repl, self._repl),
                    out_shardings=(self._cache_sh, self._repl))
        return self._window_cache[key2]

    # ------------------------------------------------------------------ #
    def free_slots(self) -> int:
        return self.slot_job.count(None)

    def has_job(self, job_id: int) -> bool:
        return job_id in self.slot_of

    def _resume_tokens(self, job: Job) -> List[int]:
        """Token stream to prefill for a job.

        Fresh job: the prompt; *the first output token is sampled from the
        prefill logits* (emitted by the next ``run_window``).
        Resumed job (preempted earlier): recompute KV for
        ``prompt + generated[:-1]`` and seed decode with the last already-
        emitted token — nothing is double-emitted.
        """
        if job.generated:
            return list(job.prompt_tokens) + list(job.generated)[:-1]
        return list(job.prompt_tokens)

    def add_job(self, job: Job) -> int:
        """Prefill one job into a free slot (batch-1 dispatch).  A job
        already holding a slot keeps it (no double admission)."""
        return self.add_jobs([job])[0]

    def add_jobs(self, jobs: Sequence[Job]) -> List[int]:
        """Admit every job not yet holding a slot.

        Attention families: ONE padded ``(batch_bucket, seq_bucket)``
        prefill dispatch for the whole group.  SSM/hybrid (or
        ``batched_prefill=False``): serial batch-1 admissions.
        Returns each job's slot, aligned with ``jobs`` (already-admitted
        jobs report the slot they hold).
        """
        todo = [j for j in jobs if not self.has_job(j.job_id)]
        if todo:
            if len(todo) > self.free_slots():
                # all-or-nothing: fail before any partial serial admission
                raise RuntimeError(
                    f"admitting {len(todo)} jobs needs {len(todo)} free "
                    f"slots, engine has {self.free_slots()}")
            serial = (not self.cfg.batched_prefill
                      or self.model_cfg.family in EXACT_PREFILL_FAMILIES)
            if serial:
                for j in todo:
                    self._admit([j])
            else:
                self._admit(todo)
        return [self.slot_of[j.job_id] for j in jobs]

    def _admit(self, jobs: Sequence[Job]) -> List[int]:
        """One prefill dispatch admitting ``jobs``."""
        if len(jobs) > self.free_slots():
            # check BEFORE the dispatch: a full engine must fail loudly,
            # not pay a prefill and then mis-assign slots
            raise RuntimeError(
                f"admitting {len(jobs)} jobs needs {len(jobs)} free slots, "
                f"engine has {self.free_slots()}")
        exact = self.model_cfg.family in EXACT_PREFILL_FAMILIES
        token_lists = [self._resume_tokens(j) for j in jobs]
        true_lens = [len(t) for t in token_lists]
        longest = max(true_lens)
        if longest > self.cfg.max_len:
            raise ValueError(
                f"prompt of {longest} tokens exceeds max_len="
                f"{self.cfg.max_len}")
        if exact:
            # recurrent state must stay clean: exact length, batch 1
            assert len(jobs) == 1, "exact-length families admit serially"
            bb, sl = 1, true_lens[0]
        else:
            bb = batch_bucket(len(jobs))
            sl = seq_bucket(longest, self.cfg.max_len,
                            min_bucket=self.cfg.prefill_bucket)
        toks = np.full((bb, sl), PAD_ID, np.int32)
        last_index = np.zeros((bb,), np.int32)
        for i, t in enumerate(token_lists):
            toks[i, : len(t)] = t
            last_index[i] = len(t) - 1
        cacheN = T.init_cache(self.model_cfg, bb, self.cfg.max_len)
        self.num_prefill_dispatches += 1
        logits, cacheN = self._prefill(self.params, jnp.asarray(toks), cacheN,
                                       jnp.asarray(last_index))
        # per-row true lengths (prefill stamps the padded length)
        cacheN["len"] = jnp.asarray(
            true_lens + [0] * (bb - len(jobs)), jnp.int32)
        slots = [s for s, owner in enumerate(self.slot_job)
                 if owner is None][: len(jobs)]
        self.cache = self._canon_cache(
            _scatter_slots(self.cache, cacheN, slots, len(jobs)))
        logits_np = np.asarray(logits)
        for i, (job, slot) in enumerate(zip(jobs, slots)):
            self.slot_job[slot] = job.job_id
            self.slot_of[job.job_id] = slot
            if job.generated:
                self.last_token[slot, 0] = job.generated[-1]
                # resume recomputes prompt + generated[:-1], and the seed
                # token's KV is written by the first decode step (+1)
                self.resume_context_tokens += true_lens[i] + 1
            else:
                first = int(np.argmax(logits_np[i, -1]))
                self._pending_first[job.job_id] = first
                self.last_token[slot, 0] = first
        return slots

    def evict_job(self, job_id: int) -> None:
        slot = self.slot_of.pop(job_id, None)
        self._pending_first.pop(job_id, None)
        self._prefill_cursor.pop(job_id, None)
        self._chunk_target.pop(job_id, None)
        self._chunk_tokens.pop(job_id, None)
        self._chunk_resumed.pop(job_id, None)
        if slot is not None:
            self.slot_job[slot] = None
            self.last_token[slot, 0] = PAD_ID

    # ------------------------------------------------------------------ #
    def run_window(self, jobs: Sequence[Job], window: int,
                   prefill_chunk: Optional[int] = None
                   ) -> Tuple[List[List[int]], List[bool]]:
        """Execute K decode steps for ``jobs`` (admitting any that lack a
        slot via one batched prefill).  Returns
        (new_tokens_per_job, finished_per_job).

        With ``prefill_chunk`` set (and the family supporting it — see
        :meth:`chunk_supported`), admission becomes *chunked*: new jobs
        claim a slot without prefilling, at most ONE job per window (the
        first incomplete one in batch order) ingests one ``prefill_chunk``-
        sized piece of its prompt, and only fully-prefilled jobs join the
        decode dispatch — a job completing its final chunk in window W
        begins decoding in window W+1.  Mid-prefill jobs emit no tokens.
        Unsupported families fall back loudly to one-shot prefill."""
        if not jobs:
            return [], []
        # swap-in: batch members with a host-stashed cache restore it
        # instead of paying recompute (KV offload tier)
        for job in jobs:
            if not self.has_job(job.job_id) and self.has_stash(job.job_id):
                self.restore_job(job)
        chunked = prefill_chunk is not None
        if chunked and not self.chunk_supported():
            self._warn_once(
                "chunk_fallback",
                f"prefill_chunk is not supported for "
                f"family={self.model_cfg.family!r} with this cache "
                "(ring/quantized KV or recurrent state); falling back "
                "to one-shot prefill")
            chunked = False
        if chunked:
            for job in jobs:
                if not self.has_job(job.job_id):
                    self._alloc_slot(job)
            # decode eligibility is decided BEFORE the chunk runs: the job
            # completing its final chunk this window decodes next window
            incomplete = [j for j in jobs
                          if self.prefill_incomplete(j.job_id)]
            decode_jobs = [j for j in jobs
                           if not self.prefill_incomplete(j.job_id)]
            if incomplete:
                self._run_chunk(incomplete[0], prefill_chunk)
        else:
            self.add_jobs(jobs)
            decode_jobs = list(jobs)
        results = {j.job_id: ([], False) for j in jobs}
        if decode_jobs:
            self._decode_jobs(decode_jobs, window, results)
        out_tokens = [list(results[j.job_id][0]) for j in jobs]
        finished = [results[j.job_id][1] for j in jobs]
        # publish each job's materialized context (prompt + generated KV,
        # incl. the seed token) — the scheduler's prefill-debt ranking and
        # the swap-vs-recompute break-even read it
        for job, seq in zip(jobs, out_tokens):
            if self.prefill_incomplete(job.job_id):
                job.prefilled_tokens = self._prefill_cursor[job.job_id]
            else:
                job.prefilled_tokens = (len(job.prompt_tokens)
                                        + job.tokens_generated + len(seq))
        return out_tokens, finished

    def _decode_jobs(self, jobs: Sequence[Job], window: int,
                     results: Dict[int, Tuple[List[int], bool]]) -> None:
        """One masked/compacted decode dispatch for ``jobs`` (all holding
        fully-prefilled slots); writes (tokens, finished) into ``results``."""
        slots = [self.slot_of[job.job_id] for job in jobs]
        prev_lens = np.asarray(self.cache["len"]).copy()
        ms = self.cfg.max_slots
        order = sorted(slots)
        db = min(batch_bucket(len(order)), ms)
        compact = self.cfg.masked_decode and db < ms
        if compact:
            # decode only the scheduled slots, padded to the batch bucket
            # (pad rows duplicate a real slot but start dead, so they are
            # frozen no-ops); gather/scatter costs one pass over the active
            # slots' cache per *window*, decode reads it K times
            gidx = np.asarray(order + [order[0]] * (db - len(order)),
                              np.int32)
            sub_cache = self._canon_cache(
                _gather_slots(self.cache, jnp.asarray(gidx)))
            sub_last = jnp.asarray(self.last_token[gidx])
            alive0 = np.zeros((db,), bool)
            alive0[: len(order)] = True
            row_of = {slot: r for r, slot in enumerate(order)}
        else:
            sub_cache = self.cache
            sub_last = jnp.asarray(self.last_token)
            if self.cfg.masked_decode:
                # full-width dispatch, but unscheduled slots stay frozen
                alive0 = np.zeros((ms,), bool)
                alive0[slots] = True
            else:
                # pre-fast-path baseline: every slot advances every window
                alive0 = np.ones((ms,), bool)
            row_of = {s: s for s in slots}
        fn = self._decode_window(window, int(sub_last.shape[0]))
        self._key, sub_key = jax.random.split(self._key)
        self.num_decode_dispatches += 1
        new_cache, toks = fn(self.params, sub_cache, sub_last,
                             jnp.asarray(alive0), sub_key)
        toks = np.asarray(toks)  # (rows, K)
        if compact:
            self.cache = self._canon_cache(
                _scatter_slots(self.cache, new_cache, order, len(order)))
        else:
            self.cache = new_cache
        lens = np.asarray(self.cache["len"]).copy()
        for job in jobs:
            slot = self.slot_of[job.job_id]
            scanned = toks[row_of[slot]].tolist()
            pending = self._pending_first.pop(job.job_id, None)
            if pending is not None:
                # first emission comes from the prefill logits; the scan's
                # K-th token is unconsumed (its cache write is rolled back)
                seq = [pending] + scanned[: window - 1]
                consumed_scanned = len(seq) - 1
            else:
                seq = scanned[:window]
                consumed_scanned = len(seq)
            cap = self.cfg.max_output
            if self.cfg.respect_job_max and job.true_output_len > 0:
                cap = min(cap, job.true_output_len)
            if self.cfg.eos_id in seq:
                cut = seq.index(self.cfg.eos_id) + 1
                dropped = len(seq) - cut
                seq = seq[:cut]
                consumed_scanned -= dropped
                fin = True
            else:
                fin = False
            room = cap - job.tokens_generated
            if len(seq) >= room:
                dropped = len(seq) - room
                seq = seq[:room]
                consumed_scanned -= dropped
                fin = True
            results[job.job_id] = (seq, fin)
            self.last_token[slot, 0] = seq[-1] if seq else PAD_ID
            # the cache pointer advances exactly one position per consumed
            # scan write — robust to both EOS freezing (which already
            # stopped advancing) and cap truncation (which did not)
            lens[slot] = prev_lens[slot] + max(consumed_scanned, 0)
        self.cache["len"] = jnp.asarray(lens)


# --------------------------------------------------------------------------- #
# Backend adapter for the ELIS frontend
# --------------------------------------------------------------------------- #


class EngineExecutor(Backend):
    """Wraps per-node InferenceEngines behind the frontend Backend ABC.
    Durations are measured wall-clock — the live-system evaluation mode.

    Every executed window is appended to ``window_log`` (node, batch,
    window, duration, tokens); ``calibrated_profile()`` fits those samples
    back onto the simulator's latency model so a live run can parameterise
    a :class:`repro.simulate.SimExecutor` (live↔sim calibration)."""

    def __init__(self, engines: Dict[int, InferenceEngine], *,
                 swap_bandwidth_bytes_s: float = 16e9,
                 swap_latency_s: float = 0.0005,
                 swap_pool_tokens: Optional[int] = None):
        self.engines = engines
        if swap_pool_tokens is not None:
            # PreemptionConfig.swap_pool_tokens: per-engine host-stash
            # watermark (None leaves any engine-level setting untouched)
            for eng in engines.values():
                eng.swap_pool_tokens = swap_pool_tokens
        self.window_log: List[Dict] = []
        #: host<->device copy model for the swap-vs-recompute break-even
        #: (``preempt_costs``) — the live copies themselves are measured
        #: wall-clock, these parameterise only the *decision*
        self.swap_bandwidth_bytes_s = swap_bandwidth_bytes_s
        self.swap_latency_s = swap_latency_s
        #: wall-clock seconds spent offloading per node since its last
        #: window — folded into the next window's reported duration so swap
        #: cost is attributed, not lost between windows
        self._pending_swap_s: Dict[int, float] = {}
        self.swapout_tokens = 0
        self.swapin_tokens = 0
        self.n_swapouts = 0
        self.n_swapins = 0
        #: per-node cached calibration fit for ``preempt_costs`` (refit
        #: after every 32 new windows; None until enough data)
        self._fit_cache: Dict[int, Tuple[int, object]] = {}

    def capacity(self, node: int) -> int:
        return self.engines[node].cfg.max_slots

    def free_capacity(self, node: int) -> int:
        return self.engines[node].free_slots()

    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float, prefill_chunk: Optional[int] = None
                ) -> ExecResult:
        eng = self.engines[node]
        t0 = time.perf_counter()
        # capacity: evict nothing here — the frontend already chose the batch;
        # engine must have slots for every scheduled job
        needed = sum(1 for job in jobs if not eng.has_job(job.job_id))
        if needed > eng.free_slots():
            raise RuntimeError(
                f"node {node}: batch needs {needed} free slots, "
                f"engine has {eng.free_slots()}"
            )
        for j in jobs:
            if eng.has_stash(j.job_id):
                self.n_swapins += 1
                self.swapin_tokens += j.prefilled_tokens
        tokens, finished = eng.run_window(jobs, window,
                                          prefill_chunk=prefill_chunk)
        dur = time.perf_counter() - t0
        dur += self._pending_swap_s.pop(node, 0.0)
        self.window_log.append({
            "node": node, "batch": len(jobs), "window": window,
            "duration_s": dur, "tokens": sum(len(t) for t in tokens),
        })
        return ExecResult(dur, tokens, finished)

    def evict(self, node: int, job: Job) -> None:
        eng = self.engines[node]
        eng.drop_stash(job.job_id)
        eng.evict_job(job.job_id)
        job.prefilled_tokens = 0

    # ------------------------------------------------------------------ #
    # KV offload tier (Backend.offload / Backend.restore)
    # ------------------------------------------------------------------ #

    def offload(self, node: int, job: Job) -> bool:
        """Swap the job's slot cache to host memory (preemption that keeps
        the KV).  Wall-clock cost is accumulated into the node's next
        window duration."""
        eng = self.engines[node]
        t0 = time.perf_counter()
        ok = eng.offload_job(job.job_id)
        if ok:
            self._pending_swap_s[node] = (
                self._pending_swap_s.get(node, 0.0)
                + (time.perf_counter() - t0))
            self.swapout_tokens += job.prefilled_tokens
            self.n_swapouts += 1
        return ok

    def restore(self, node: int, job: Job) -> bool:
        """Explicit swap-in (execute() also restores lazily)."""
        eng = self.engines[node]
        if not eng.has_stash(job.job_id):
            return False
        eng.restore_job(job)
        return True

    def preempt_costs(self, node: int, job: Job
                      ) -> Optional[Tuple[float, float]]:
        """(swap_round_trip_s, recompute_s) estimates for preempting
        ``job`` — the ``auto`` :class:`PreemptPolicy` break-even input.
        Swap cost: two host<->device copies of the job's KV footprint at
        the configured bandwidth.  Recompute cost: the job's context
        through the *calibrated* prefill rate (None until enough measured
        windows exist — the caller then falls back to recompute)."""
        n = job.prefilled_tokens
        if n <= 0:
            return None
        eng = self.engines[node]
        mc = eng.model_cfg
        kv_bytes = (2 * mc.n_layers * (mc.n_kv_heads or mc.n_heads)
                    * mc.head_dim * jnp.dtype(mc.dtype).itemsize)
        swap_s = 2.0 * (self.swap_latency_s
                        + n * kv_bytes / self.swap_bandwidth_bytes_s)
        prof = self._cached_fit(node)
        if prof is None:
            return None
        rec_s = prof.prefill_ms(1, n) / 1000.0
        return swap_s, rec_s

    def _cached_fit(self, node: int):
        n_log = len(self.window_log)
        cached = self._fit_cache.get(node)
        if cached is not None and n_log - cached[0] < 32:
            return cached[1]
        try:
            prof = self.calibrated_profile(nodes=[node])
        except ValueError:
            prof = None
        self._fit_cache[node] = (n_log, prof)
        return prof

    # ------------------------------------------------------------------ #
    def node_counters(self) -> Dict[int, Dict[str, int]]:
        """Per-node compile/dispatch counters — a recompile storm (or
        dead-FLOPs regression) on one pod must be attributable to that pod,
        not smeared across the aggregate."""
        windows = {n: 0 for n in self.engines}
        for rec in self.window_log:
            windows[rec["node"]] = windows.get(rec["node"], 0) + 1
        return {
            n: {"prefill_traces": eng.num_prefill_traces,
                "prefill_dispatches": eng.num_prefill_dispatches,
                "decode_traces": eng.num_decode_traces,
                "decode_dispatches": eng.num_decode_dispatches,
                "chunk_traces": eng.num_chunk_traces,
                "chunk_dispatches": eng.num_chunk_dispatches,
                "resume_context_tokens": eng.resume_context_tokens,
                "windows_executed": windows.get(n, 0)}
            for n, eng in self.engines.items()
        }

    def counters(self) -> Dict[str, int]:
        """Aggregated compile/dispatch counters across this executor's
        engines (the recompile-storm / dead-FLOPs introspection hooks);
        :meth:`node_counters` keeps the per-pod breakdown."""
        agg = {"prefill_traces": 0, "prefill_dispatches": 0,
               "decode_traces": 0, "decode_dispatches": 0,
               "chunk_traces": 0, "chunk_dispatches": 0,
               "resume_context_tokens": 0,
               "windows_executed": len(self.window_log),
               "swapouts": self.n_swapouts, "swapins": self.n_swapins,
               "swapout_tokens": self.swapout_tokens,
               "swapin_tokens": self.swapin_tokens,
               "stash_evictions": sum(e.n_stash_evictions
                                      for e in self.engines.values()),
               "stash_evicted_tokens": sum(e.stash_evicted_tokens
                                           for e in self.engines.values())}
        for per in self.node_counters().values():
            for k in ("prefill_traces", "prefill_dispatches",
                      "decode_traces", "decode_dispatches",
                      "chunk_traces", "chunk_dispatches",
                      "resume_context_tokens"):
                agg[k] += per[k]
        return agg

    def calibrated_profile(self, name: str = "live-calibrated",
                           params_b: Optional[float] = None,
                           preempt_batch: int = 64,
                           mem_limit_frac: float = 0.4,
                           nodes: Optional[Sequence[int]] = None):
        """Fit the simulator's latency model to the measured windows.

        The model (``repro.simulate.profiles``):
            duration ≈ overhead + window · d1 · (1 + slowdown · (batch-1))
        is linear in (overhead, d1, d1·slowdown); a least-squares fit over
        ``window_log`` (dropping each (node, batch, window) shape's first
        occurrence, which pays XLA compile) recovers ``decode_ms_1`` and
        ``batch_slowdown``.  Returns a :class:`ModelProfile` usable by
        ``SimExecutor`` — simulate *this* live engine at cluster scale.

        ``nodes`` restricts the fit to a node subset — on a heterogeneous
        pod fleet (different TP degrees / hardware) each pod gets its own
        profile; :meth:`calibrated_node_profiles` fits all of them.
        """
        from repro.simulate.profiles import (CALIBRATION_MEAN_TOKENS,
                                             ModelProfile)
        keep = set(self.engines if nodes is None else nodes)
        unknown = keep - set(self.engines)
        if unknown:
            raise ValueError(
                f"calibrated_profile: unknown node(s) {sorted(unknown)}; "
                f"this executor drives nodes {sorted(self.engines)}")
        log = [rec for rec in self.window_log if rec["node"] in keep]
        seen = set()
        samples = []
        for rec in log:
            key = (rec["node"], rec["batch"], rec["window"])
            if key in seen:
                samples.append(rec)
            else:
                seen.add(key)  # first occurrence pays compile — drop it
        if not samples:
            samples = list(log)
        if not samples:
            raise ValueError(
                "calibrated_profile: window_log holds no executed windows "
                f"for node(s) {sorted(keep)} — run at least one window via "
                "execute() before calibrating")
        w = np.array([r["window"] for r in samples], float)
        b = np.array([r["batch"] for r in samples], float)
        d = np.array([r["duration_s"] for r in samples], float)
        X = np.stack([np.ones_like(w), w, w * (b - 1)], axis=1)
        if np.linalg.matrix_rank(X) >= 3:
            (o, a, c), *_ = np.linalg.lstsq(X, d, rcond=None)
            a = float(max(a, 1e-9))
            slowdown = float(min(max(c / a, 0.0), 10.0))
            overhead = float(max(o, 0.0))
        else:
            # degenerate design (single batch size or window length):
            # attribute everything to the per-token rate
            a = float(max(np.mean(d / np.maximum(w, 1.0)), 1e-9))
            slowdown = 0.0
            overhead = 0.0
        #: per-window fixed cost (dispatch + host loop) the latency model's
        #: intercept absorbed — feed it to SimExecutor.sched_overhead_s so
        #: a calibrated replay prices whole windows, not just tokens
        self.fit_overhead_s = overhead
        eng = self.engines[min(keep)]
        mc = eng.model_cfg
        if params_b is None:
            # rough dense-transformer parameter count from the config
            params_b = 12 * mc.n_layers * mc.d_model ** 2 / 1e9
        return ModelProfile(
            name=name, params_b=params_b,
            avg_latency_ms=a * 1000.0 * CALIBRATION_MEAN_TOKENS,
            n_layers=mc.n_layers,
            n_kv_heads=mc.n_kv_heads or mc.n_heads,
            head_dim=mc.head_dim,
            preempt_batch=preempt_batch, mem_limit_frac=mem_limit_frac,
            batch_slowdown=slowdown,
        )

    def calibrated_node_profiles(self, prefix: str = "live-node", **kw
                                 ) -> Dict[int, "object"]:
        """Per-pod live fits: {node: ModelProfile}.  Also records each
        pod's fitted per-window overhead in ``node_fit_overhead_s`` (feed
        the mean to ``SimExecutor.sched_overhead_s`` for a replay that
        prices whole windows)."""
        profs, over = {}, {}
        for n in sorted(self.engines):
            profs[n] = self.calibrated_profile(name=f"{prefix}{n}",
                                               nodes=[n], **kw)
            over[n] = self.fit_overhead_s
        self.node_fit_overhead_s = over
        return profs

    def node_token_cost(self) -> Dict[int, float]:
        """Fitted seconds-per-token per node — the ``least_eta`` placement
        input, measured from this executor's own window log instead of
        assumed uniform."""
        return {n: p.decode_ms_1 / 1000.0
                for n, p in self.calibrated_node_profiles().items()}


# --------------------------------------------------------------------------- #
# Data-parallel pod construction
# --------------------------------------------------------------------------- #


def make_tp_pods(model_cfg, params, cfg: Optional[EngineConfig] = None, *,
                 n_pods: int = 1, tp: int = 1, devices=None
                 ) -> Dict[int, InferenceEngine]:
    """Build ``n_pods`` data-parallel serving pods, each a ``tp``-way
    tensor-parallel :class:`InferenceEngine` on its own **disjoint**
    single-axis ``("model",)`` mesh — the live-cluster topology the
    frontend's placement policies drive (each pod registers as one node in
    ``GlobalState``; no collective ever crosses pods).

    One host copy of ``params`` is device_put onto every pod's mesh.
    ``tp=1`` pods are plain single-device engines (no mesh, no collective
    overhead)."""
    if tp <= 1:
        return {n: InferenceEngine(model_cfg, params, cfg)
                for n in range(n_pods)}
    from repro.launch.mesh import make_mesh
    devices = list(jax.devices() if devices is None else devices)
    need = n_pods * tp
    if len(devices) < need:
        raise RuntimeError(
            f"{n_pods} pods x TP={tp} need {need} devices, have "
            f"{len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return {
        n: InferenceEngine(
            model_cfg, params, cfg,
            mesh=make_mesh((tp,), ("model",),
                           devices=devices[n * tp:(n + 1) * tp]))
        for n in range(n_pods)
    }
