"""Live-engine evaluation: ISRTF vs FCFS on the real JAX engine (reduced
model, wall-clock timed) — validates that the mechanism's gains survive on
a real continuous-batching execution engine, not only in simulation.
Drives the engine through the online :class:`ElisServer` API."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElisServer,
    FrontendConfig,
    OraclePredictor,
    PreemptionConfig,
    Request,
    RequestOptions,
    SchedulerConfig,
    summarize,
)
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params

from benchmarks.common import save_results


def _requests(n, seed, max_tokens=48):
    rng = np.random.RandomState(seed)
    reqs = []
    t = 0.0
    for i in range(n):
        # bimodal lengths: mostly short, some long (LMSYS-like skew)
        length = int(rng.choice([8, 12, 48], p=[0.5, 0.3, 0.2]))
        t += float(rng.gamma(0.73, 0.4))
        reqs.append(Request(
            prompt=f"p{i}", prompt_tokens=[10 + i % 50, 20, 30],
            arrival_time=t, true_output_len=length,
            options=RequestOptions(max_tokens=max_tokens)))
    return reqs


def run(quick: bool = False):
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = 8 if quick else 16
    rows = []
    for policy in ("fcfs", "isrtf"):
        engine = InferenceEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=256, max_output=48, eos_id=-1,
            respect_job_max=True))
        server = ElisServer(
            FrontendConfig(
                n_nodes=1,
                scheduler=SchedulerConfig(policy=policy, window=8,
                                          batch_size=2),
                preemption=PreemptionConfig(enabled=policy != "fcfs"),
            ),
            OraclePredictor() if policy != "fcfs" else None,
            EngineExecutor({0: engine}),
        )
        for r in _requests(n, seed=3):
            server.submit(r)
        done = server.drain()
        m = summarize(done)
        rows.append({"policy": policy, "n_jobs": len(done),
                     "jct_mean_s": round(m["jct_mean"], 3),
                     "queuing_delay_mean_s": round(m["queuing_delay_mean"], 3),
                     "preemptions": m["preemptions"]})
    imp = 100 * (rows[0]["jct_mean_s"] - rows[1]["jct_mean_s"]) / rows[0]["jct_mean_s"]
    rows.append({"live_isrtf_vs_fcfs_improvement_pct": round(imp, 2)})
    save_results("live_engine", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
