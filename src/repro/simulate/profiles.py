"""Calibrated per-model execution profiles (paper Table 4 / Appendix A).

The paper reports, per model on an NVIDIA A100: the average end-to-end request
latency over 500 LMSYS prompts (Table 4) and the preemption-onset batch size
under a vLLM memory limit (Table 6).  We invert those into a latency model:

    iter_time(b, tokens) = overhead + tokens * decode_ms(b)
    decode_ms(b)         = decode_ms_1 * (1 + batch_slowdown * (b - 1))
    prefill_ms(b, n)     = n * prefill_ms_per_token

``decode_ms_1`` is calibrated so that mean-length (≈168-token) responses at
batch 1 match Table 4's average latency.  The batch-slowdown coefficient
models the memory-bound decode regime (larger batches raise per-iteration
time sub-linearly; throughput still improves).

The KV memory model reproduces Appendix A: preemption begins when resident
tokens exceed ``mem_limit_frac * HBM - weights``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

#: mean response length of the workload used for calibration (tokens)
CALIBRATION_MEAN_TOKENS = 168.0
#: H100 vs A100 decode speed (HBM3/HBM2e bandwidth; decode is memory-bound)
H100_SPEEDUP = 3.35
#: paper §6.2: measured scheduling overhead (batching + predictor), ms
SCHED_OVERHEAD_MS = 11.04
A100_HBM_BYTES = 80 * 1024**3


@dataclass(frozen=True)
class ModelProfile:
    name: str
    params_b: float            # billions
    avg_latency_ms: float      # paper Table 4
    n_layers: int
    n_kv_heads: int
    head_dim: int
    preempt_batch: int         # paper Table 6 (appendix)
    mem_limit_frac: float      # paper Table 6 vLLM memory limit
    batch_slowdown: float = 0.08
    prefill_speedup: float = 8.0  # prefill is compute-bound ≈ 8x decode rate

    #: hardware speed multiplier (1.0 = the A100 the paper profiled on;
    #: the Fig-7 scaling study ran on H100s ≈ 3.35x decode bandwidth)
    speedup: float = 1.0

    def scaled(self, speedup: float) -> "ModelProfile":
        import dataclasses

        return dataclasses.replace(self, speedup=speedup)

    @property
    def decode_ms_1(self) -> float:
        return self.avg_latency_ms / CALIBRATION_MEAN_TOKENS / self.speedup

    def decode_ms(self, batch: int) -> float:
        return self.decode_ms_1 * (1.0 + self.batch_slowdown * (batch - 1))

    def prefill_ms(self, batch: int, n_tokens: int) -> float:
        return n_tokens * self.decode_ms(batch) / self.prefill_speedup

    @property
    def kv_bytes_per_token(self) -> int:
        # fp16 K and V
        return 2 * self.n_layers * self.n_kv_heads * self.head_dim * 2

    @property
    def weight_bytes(self) -> int:
        return int(self.params_b * 1e9 * 2)

    def kv_capacity_tokens(self) -> int:
        budget = self.mem_limit_frac * A100_HBM_BYTES - self.weight_bytes
        return max(int(budget // self.kv_bytes_per_token), 0)


#: paper Table 4 + Table 6 (+ model cards for dims)
PROFILES: Dict[str, ModelProfile] = {
    p.name: p
    for p in [
        ModelProfile("opt6.7", 6.7, 1315.5, n_layers=32, n_kv_heads=32,
                     head_dim=128, preempt_batch=30, mem_limit_frac=0.40),
        ModelProfile("opt13", 13.0, 2643.2, n_layers=40, n_kv_heads=40,
                     head_dim=128, preempt_batch=60, mem_limit_frac=0.40),
        ModelProfile("lam7", 7.0, 6522.2, n_layers=32, n_kv_heads=32,
                     head_dim=128, preempt_batch=40, mem_limit_frac=0.30),
        ModelProfile("lam13", 13.0, 8610.2, n_layers=40, n_kv_heads=40,
                     head_dim=128, preempt_batch=120, mem_limit_frac=0.90),
        ModelProfile("vic", 13.0, 2964.9, n_layers=40, n_kv_heads=40,
                     head_dim=128, preempt_batch=90, mem_limit_frac=0.40),
    ]
}


def avg_request_rate(profile: ModelProfile, batch_size: int) -> float:
    """Paper §6.2: AVG.RequestRate = 1000 / AVG.Latency * batchsize."""
    return 1000.0 / profile.avg_latency_ms * batch_size
