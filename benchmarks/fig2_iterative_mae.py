"""Paper Fig. 2(b): predictor MAE falls as iterations progress.

Each scheduling iteration appends 50 more response tokens to the predictor's
input; the paper's key intuition is that accuracy improves monotonically
with the iteration index.  We evaluate the trained predictor's MAE bucketed
by step and additionally report relative MAE (MAE / mean remaining) since
remaining lengths shrink with step by construction.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results
from benchmarks.table2_predictor import trained_predictor


def run(quick: bool = False):
    pred, test = trained_predictor(quick)
    rows = []
    for k in range(6):
        sub = [s for s in test if s.step == k]
        if len(sub) < 10:
            continue
        ev = pred.evaluate(sub)
        mean_rem = float(np.mean([s.remaining for s in sub]))
        rows.append({
            "step": k,
            "n": len(sub),
            "mae": round(ev["mae"], 2),
            "relative_mae": round(ev["mae"] / mean_rem, 3),
            "mean_remaining": round(mean_rem, 1),
        })
    save_results("fig2_iterative_mae", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
