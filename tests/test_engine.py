"""Live JAX engine: greedy exactness, windows, preemption resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Job
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine, SamplerConfig
from repro.models import forward, init_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n):
    """Naive greedy decode via repeated full forward (the oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n):
        logits, _ = forward(params, cfg, {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_greedy_matches_forward(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1,
        sampler=SamplerConfig(temperature=0.0)))
    job = Job(job_id=0, prompt="x", prompt_tokens=[11, 22, 33, 44],
              arrival_time=0.0)
    toks, fin = eng.run_window([job], 10)
    want = greedy_reference(cfg, params, [11, 22, 33, 44], 10)
    assert toks[0] == want


def test_engine_windows_continue_exactly(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1))
    job = Job(job_id=1, prompt="x", prompt_tokens=[5, 6, 7], arrival_time=0.0)
    t1, _ = eng.run_window([job], 6)
    job.generated.extend(t1[0])
    t2, _ = eng.run_window([job], 6)
    want = greedy_reference(cfg, params, [5, 6, 7], 12)
    assert t1[0] + t2[0] == want


def test_preempt_resume_is_exact(setup):
    """Evict + recompute-resume must continue the identical greedy stream."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=128, max_output=64, eos_id=-1))
    job = Job(job_id=2, prompt="x", prompt_tokens=[9, 8, 7], arrival_time=0.0)
    t1, _ = eng.run_window([job], 5)
    job.generated.extend(t1[0])
    eng.evict_job(job.job_id)          # preemption
    assert eng.free_slots() == 1
    t2, _ = eng.run_window([job], 5)   # recompute-resume
    job.generated.extend(t2[0])
    want = greedy_reference(cfg, params, [9, 8, 7], 10)
    assert job.generated == want
    assert job.generated[:5] == t1[0]


def test_two_slots_independent(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1))
    j0 = Job(job_id=3, prompt="a", prompt_tokens=[1, 2, 3], arrival_time=0.0)
    j1 = Job(job_id=4, prompt="b", prompt_tokens=[4, 5, 6, 7, 8],
             arrival_time=0.0)
    toks, _ = eng.run_window([j0, j1], 8)
    assert toks[0] == greedy_reference(cfg, params, [1, 2, 3], 8)
    assert toks[1] == greedy_reference(cfg, params, [4, 5, 6, 7, 8], 8)


def test_eos_truncates_and_finishes(setup):
    cfg, params = setup
    # find the first greedy token and use it as the EOS id -> finishes at once
    first = greedy_reference(cfg, params, [11, 22, 33, 44], 1)[0]
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=128, max_output=64, eos_id=first))
    job = Job(job_id=5, prompt="x", prompt_tokens=[11, 22, 33, 44],
              arrival_time=0.0)
    toks, fin = eng.run_window([job], 10)
    assert fin[0] and toks[0] == [first]


def test_executor_capacity_guard(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(max_slots=1, max_len=128))
    ex = EngineExecutor({0: eng})
    jobs = [Job(job_id=i + 10, prompt="x", prompt_tokens=[1, 2],
                arrival_time=0.0) for i in range(2)]
    with pytest.raises(RuntimeError):
        ex.execute(0, jobs, 5, 0.0)
