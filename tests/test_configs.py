"""Config registry and per-arch invariants."""
import pytest

from repro.configs import get_config, list_archs

EXPECTED = {
    "qwen2-vl-7b": dict(family="vlm", n_layers=28, d_model=3584, n_heads=28,
                        n_kv_heads=4, d_ff=18944, vocab_size=152064),
    "yi-6b": dict(family="dense", n_layers=32, d_model=4096, n_heads=32,
                  n_kv_heads=4, d_ff=11008, vocab_size=64000),
    "mamba2-130m": dict(family="ssm", n_layers=24, d_model=768,
                        vocab_size=50280),
    "mixtral-8x7b": dict(family="moe", n_layers=32, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=14336, vocab_size=32000),
    "llama3.2-3b": dict(family="dense", n_layers=28, d_model=3072, n_heads=24,
                        n_kv_heads=8, d_ff=8192, vocab_size=128256),
    "qwen2-moe-a2.7b": dict(family="moe", n_layers=24, d_model=2048,
                            n_heads=16, n_kv_heads=16, d_ff=1408,
                            vocab_size=151936),
    "qwen1.5-32b": dict(family="dense", n_layers=64, d_model=5120, n_heads=40,
                        n_kv_heads=40, d_ff=27392, vocab_size=152064),
    "qwen2-1.5b": dict(family="dense", n_layers=28, d_model=1536, n_heads=12,
                       n_kv_heads=2, d_ff=8960, vocab_size=151936),
    "whisper-large-v3": dict(family="audio", n_layers=32, d_model=1280,
                             n_heads=20, n_kv_heads=20, d_ff=5120,
                             vocab_size=51866),
    "zamba2-7b": dict(family="hybrid", n_layers=81, d_model=3584, n_heads=32,
                      n_kv_heads=32, d_ff=14336, vocab_size=32000),
}


def test_all_archs_registered():
    assert set(list_archs()) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_config(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 4 and r.d_model <= 512
    if r.moe.enabled:
        assert r.moe.num_experts <= 4


def test_moe_specifics():
    mx = get_config("mixtral-8x7b")
    assert mx.moe.num_experts == 8 and mx.moe.top_k == 2
    assert mx.attention_type == "swa"
    qm = get_config("qwen2-moe-a2.7b")
    assert qm.moe.num_experts == 60 and qm.moe.top_k == 4
    assert qm.moe.num_shared_experts == 4


def test_ssm_specifics():
    m2 = get_config("mamba2-130m")
    assert m2.ssm.d_state == 128 and m2.attn_free
    z = get_config("zamba2-7b")
    assert z.ssm.d_state == 64 and z.hybrid.attn_every == 6


def test_param_counts_close_to_public():
    # within 25% of the public parameter counts
    approx = {
        "yi-6b": 6.1e9, "mixtral-8x7b": 46.7e9, "mamba2-130m": 0.13e9,
        "llama3.2-3b": 3.2e9, "qwen2-1.5b": 1.5e9, "qwen2-vl-7b": 7.6e9,
        "zamba2-7b": 7.0e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.25, (arch, got, want)
