"""ELIS frontend (Algorithm 1) against a scripted executor."""
from typing import List, Sequence

import pytest

from repro.core import (
    ELISFrontend,
    ExecResult,
    FrontendConfig,
    Job,
    OraclePredictor,
    PreemptionConfig,
    SchedulerConfig,
)


class ScriptedExecutor:
    """Deterministic executor: every window takes 1s, emits token id 7."""

    def __init__(self):
        self.calls = []
        self.evictions = []

    def execute(self, node, jobs: Sequence[Job], window, now) -> ExecResult:
        self.calls.append((now, node, [j.job_id for j in jobs]))
        toks, fin = [], []
        for j in jobs:
            n = min(window, j.true_output_len - j.tokens_generated)
            toks.append([7] * n)
            fin.append(j.tokens_generated + n >= j.true_output_len)
        return ExecResult(1.0, toks, fin)

    def evict(self, node, job):
        self.evictions.append(job.job_id)


def mk_jobs(lens, arrivals=None):
    arrivals = arrivals or [0.0] * len(lens)
    return [
        Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1], arrival_time=a,
            true_output_len=l)
        for i, (l, a) in enumerate(zip(lens, arrivals))
    ]


def run(policy, lens, arrivals=None, batch=2, nodes=1, preempt=True):
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=nodes,
            scheduler=SchedulerConfig(policy=policy, window=50,
                                      batch_size=batch),
            preemption=PreemptionConfig(enabled=preempt, margin=10,
                                        max_fraction=1.0),
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        ScriptedExecutor(),
    )
    jobs = mk_jobs(lens, arrivals)
    for j in jobs:
        fe.submit(j)
    done = fe.run()
    return {j.job_id: j for j in done}, fe


def test_all_jobs_finish_exact_lengths():
    done, _ = run("fcfs", [120, 49, 50, 51])
    assert len(done) == 4
    for j in done.values():
        assert j.tokens_generated == j.true_output_len
        assert j.finished and j.finish_time is not None


def test_isrtf_runs_short_job_first():
    # batch=1: strict serialization; ISRTF must pick the short job
    done, fe = run("isrtf", [500, 40], batch=1)
    assert done[1].finish_time < done[0].finish_time


def test_fcfs_head_of_line_blocking():
    # FCFS with batch=1: the long job 0 blocks the short job 1
    done, _ = run("fcfs", [500, 40], batch=1, preempt=False)
    assert done[1].finish_time > done[0].finish_time - 1e-9


def test_isrtf_beats_fcfs_mean_jct_here():
    lens = [400, 30, 30, 30, 30, 30]
    d_f, _ = run("fcfs", lens, batch=1, preempt=False)
    d_i, _ = run("isrtf", lens, batch=1)
    mean = lambda d: sum(j.jct() for j in d.values()) / len(d)
    assert mean(d_i) < mean(d_f)


def test_window_iterations_counted():
    done, _ = run("fcfs", [120])
    assert done[0].n_iterations == 3  # 50 + 50 + 20


def test_preemption_happens_and_is_counted():
    # long job running alone; a very short job arrives -> displaces it
    done, fe = run("isrtf", [1000, 10], arrivals=[0.0, 1.5], batch=1)
    assert done[0].n_preemptions >= 1
    assert 0 in fe.executor.evictions
    assert done[1].finish_time < done[0].finish_time


def test_no_preemption_when_disabled():
    done, fe = run("fcfs", [1000, 10], arrivals=[0.0, 1.5], batch=1,
                   preempt=False)
    assert done[0].n_preemptions == 0
    assert fe.executor.evictions == [] or set(fe.executor.evictions) <= {0, 1}


def test_load_balancer_spreads_jobs():
    done, fe = run("fcfs", [100] * 6, nodes=3)
    nodes = {j.node for j in done.values()}
    assert nodes == {0, 1, 2}


def test_queuing_delay_accounting():
    done, _ = run("fcfs", [100, 100, 100], batch=1, preempt=False)
    # with a 1s/window scripted executor, later jobs accrue queuing delay
    delays = [done[i].queuing_delay for i in range(3)]
    assert delays[0] < delays[1] < delays[2]
    for j in done.values():
        assert j.queuing_delay <= j.jct() + 1e-9
