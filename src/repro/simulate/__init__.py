from repro.simulate.executor import SimExecutor
from repro.simulate.profiles import (
    PROFILES,
    SCHED_OVERHEAD_MS,
    ModelProfile,
    avg_request_rate,
)
from repro.simulate.runner import (
    ARRIVAL_PROCESSES,
    ExperimentConfig,
    compare_policies,
    make_predictor,
    requests_to_jobs,
    run_experiment,
)
from repro.simulate.scale import (
    ScaleResult,
    ScaleSimConfig,
    ScaleSimulator,
    run_exact_reference,
)

__all__ = [
    "ARRIVAL_PROCESSES",
    "ExperimentConfig",
    "ModelProfile",
    "PROFILES",
    "SCHED_OVERHEAD_MS",
    "ScaleResult",
    "ScaleSimConfig",
    "ScaleSimulator",
    "SimExecutor",
    "avg_request_rate",
    "compare_policies",
    "make_predictor",
    "requests_to_jobs",
    "run_exact_reference",
    "run_experiment",
]
