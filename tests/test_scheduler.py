"""Scheduler policy unit + property tests (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Job,
    NoisyOraclePredictor,
    OraclePredictor,
    PreemptionConfig,
    SchedulerConfig,
    make_policy,
    select_preemptions,
)
from repro.core.frontend import batch_effective


def mk_job(i, arrival=0.0, true_len=100, generated=0):
    j = Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1, 2, 3],
            arrival_time=arrival, true_output_len=true_len)
    j.generated = [7] * generated
    return j


def test_fcfs_orders_by_arrival():
    pol = make_policy(SchedulerConfig(policy="fcfs"), None)
    jobs = [mk_job(0, arrival=5.0), mk_job(1, arrival=1.0)]
    pris = batch_effective(pol, jobs, now=10.0)
    assert pris[1] < pris[0]


def test_isrtf_prefers_short_remaining():
    pol = make_policy(SchedulerConfig(policy="isrtf"), OraclePredictor())
    jobs = [mk_job(0, true_len=500), mk_job(1, true_len=20)]
    pris = batch_effective(pol, jobs, now=0.0)
    assert pris[1] < pris[0]


def test_isrtf_priority_updates_with_progress():
    pol = make_policy(SchedulerConfig(policy="isrtf"), OraclePredictor())
    j = mk_job(0, true_len=500)
    p0 = batch_effective(pol, [j], now=0.0)[0]
    j.generated = [7] * 450
    p1 = batch_effective(pol, [j], now=1.0)[0]
    assert p1 < p0


def test_sjf_keeps_first_estimate():
    pol = make_policy(SchedulerConfig(policy="sjf"), OraclePredictor())
    j = mk_job(0, true_len=300)
    p0 = batch_effective(pol, [j], now=0.0)[0]
    j.true_output_len = 999  # oracle would now say 999 - but SJF is one-shot
    j.generated = [7] * 50
    p1 = batch_effective(pol, [j], now=1.0)[0]
    assert p1 == pytest.approx(p0 - 50)


def test_aging_prevents_starvation():
    cfg = SchedulerConfig(policy="isrtf", aging_rate=10.0)
    pol = make_policy(cfg, OraclePredictor())
    old = mk_job(0, true_len=1000)
    old.record_enqueue(0.0)
    young = mk_job(1, true_len=10)
    young.record_enqueue(99.9)
    pris = batch_effective(pol, [old, young], now=100.0)
    assert pris[0] < pris[1]  # 1000 - 10*100 < 10


def test_mlfq_demotes_by_service():
    pol = make_policy(SchedulerConfig(policy="mlfq"), None)
    fresh = mk_job(0, arrival=50.0, generated=0)
    served = mk_job(1, arrival=0.0, generated=300)
    pris = batch_effective(pol, [fresh, served], now=60.0)
    assert pris[0] < pris[1]


def test_requires_predictor():
    with pytest.raises(ValueError):
        make_policy(SchedulerConfig(policy="isrtf"), None)
    with pytest.raises(ValueError):
        make_policy(SchedulerConfig(policy="nope"), OraclePredictor())


# --------------------------------------------------------------------------- #
# Preemption policy properties
# --------------------------------------------------------------------------- #


@given(
    run=st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
    wait=st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
    margin=st.floats(0, 100),
    frac=st.floats(0, 1),
)
@settings(max_examples=200, deadline=None)
def test_preemption_properties(run, wait, margin, frac):
    running = [(p, mk_job(100 + i)) for i, p in enumerate(run)]
    waiting = [(p, mk_job(200 + i)) for i, p in enumerate(wait)]
    cfg = PreemptionConfig(enabled=True, margin=margin, max_fraction=frac)
    swaps = select_preemptions(running, waiting, cfg)
    # budget respected
    assert len(swaps) <= int(len(running) * frac)
    # each swap strictly beats the victim by the margin
    run_pri = {j.job_id: p for p, j in running}
    wait_pri = {j.job_id: p for p, j in waiting}
    for victim, repl in swaps:
        assert wait_pri[repl.job_id] + margin < run_pri[victim.job_id]
    # no duplicates
    assert len({v.job_id for v, _ in swaps}) == len(swaps)
    assert len({r.job_id for _, r in swaps}) == len(swaps)


def test_preemption_disabled():
    running = [(100.0, mk_job(0))]
    waiting = [(1.0, mk_job(1))]
    assert select_preemptions(running, waiting,
                              PreemptionConfig(enabled=False)) == []


@given(st.lists(st.integers(1, 1000), min_size=2, max_size=30))
@settings(max_examples=100, deadline=None)
def test_noisy_oracle_positive_and_decaying_sigma(lens):
    pred = NoisyOraclePredictor(seed=1)
    for i, l in enumerate(lens):
        j = mk_job(i, true_len=l)
        p = pred.init(j)
        assert p >= 1.0
    assert pred._sigma(5) < pred._sigma(0)
    assert pred._sigma(100) == pred.sigma_floor
