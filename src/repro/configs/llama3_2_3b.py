"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-1B family] — small llama3 dense GQA.

28L, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 128256.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="llama3.2-3b",
        family="dense",
        source="hf:meta-llama/Llama-3.2-1B",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta=500_000.0,
        attention_type="full",
        long_context_mode="sliding_window",
        max_position_embeddings=131072,
    )
)
