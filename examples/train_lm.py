"""Train a small language model end-to-end (training-substrate demo).

    PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 150

Uses the reduced variant of any assigned architecture, the synthetic
workload's token stream as data, the pure-JAX AdamW, per-layer remat, and
msgpack checkpointing. Loss must fall — asserted at the end.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data import WorkloadGenerator
from repro.models import init_params, loss_fn
from repro.training import AdamWConfig, adamw_init, adamw_update, save_checkpoint


def data_stream(cfg, batch_size, seq_len, seed=0):
    """Next-token batches over concatenated synthetic request streams."""
    gen = WorkloadGenerator(seed=seed)
    buf = []
    while True:
        while len(buf) < batch_size * (seq_len + 1):
            r = gen.sample_request()
            buf.extend(t % cfg.vocab_size for t in r.prompt_tokens)
            buf.extend(t % cfg.vocab_size for t in r.output_tokens)
        chunk = np.asarray(buf[: batch_size * (seq_len + 1)], np.int32)
        buf = buf[batch_size * (seq_len + 1):]
        chunk = chunk.reshape(batch_size, seq_len + 1)
        yield {"tokens": jnp.asarray(chunk[:, :-1]),
               "labels": jnp.asarray(chunk[:, 1:])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=list(list_archs()))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="experiments/lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"training {cfg.arch_id}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} ({cfg.param_count()/1e6:.1f}M params)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                          weight_decay=0.01)
    opt_state = adamw_init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (l, aux), grads = jax.value_and_grad(
            lambda p, b: loss_fn(p, cfg, b, remat=True), has_aux=True
        )(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        return params, opt_state, l

    it = data_stream(cfg, args.batch, args.seq)
    t0 = time.time()
    first = None
    for i in range(args.steps):
        params, opt_state, loss = step(params, opt_state, next(it))
        if i == 0:
            first = float(loss)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({time.time()-t0:.0f}s)")
    final = float(loss)
    os.makedirs(args.ckpt, exist_ok=True)
    save_checkpoint(args.ckpt, args.steps, params,
                    metadata={"loss": final, "arch": args.arch})
    print(f"loss {first:.3f} -> {final:.3f}; checkpoint in {args.ckpt}")
    assert final < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
