"""Token samplers."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0            # 0 = full softmax


def sample(logits: jnp.ndarray, key, cfg: SamplerConfig,
           active=None, pad_token: int = 0) -> jnp.ndarray:
    """logits (B, V) -> (B,) int32.

    ``active`` (B,) bool — rows marked inactive (empty or EOS-frozen decode
    slots sharing a dispatch) emit ``pad_token`` instead of a sample.  The
    RNG key consumption is identical with or without the mask, so masked
    and unmasked engines draw the same stochastic streams for live rows.
    """
    if cfg.temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        logits = logits / cfg.temperature
        if cfg.top_k > 0:
            top, _ = jax.lax.top_k(logits, cfg.top_k)
            kth = top[..., -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
        tok = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    if active is not None:
        tok = jnp.where(active, tok, jnp.int32(pad_token))
    return tok
