"""Minimal stand-in for the ``hypothesis`` property-testing package.

Activated by ``tests/conftest.py`` ONLY when the real package is not
installed (this container cannot pip-install).  It implements just the
surface the test-suite uses — ``@given``/``@settings`` with the strategies
in :mod:`tests._shims.hypothesis.strategies` — by drawing a fixed number of
pseudo-random examples from a deterministically seeded RNG.  No shrinking,
no example database; failures report the drawn arguments via the normal
assertion traceback.
"""
from __future__ import annotations

import functools
import inspect
import random

from . import strategies  # noqa: F401  (imported for `from hypothesis import strategies`)

__version__ = "0.0-shim"

_DEFAULT_MAX_EXAMPLES = 50


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Decorator recording example-count settings on the test function."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Decorator: call the test with examples drawn from the strategies."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xE1157)
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # hide the drawn parameters from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis_shim = True
        return wrapper

    return deco
