"""Qwen2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4
plus 4 shared experts.

24L, d_model 2048, 16 heads (GQA kv=16), routed expert d_ff 1408,
shared-expert path d_ff 4*1408=5632, vocab 151936, QKV bias.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        attention_type="full",
        long_context_mode="sliding_window",
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            num_shared_experts=4,
            expert_d_ff=1408,
            shared_d_ff=5632,
            norm_topk_prob=False,
        ),
        max_position_embeddings=32768,
    )
)
