"""Beyond-paper ablations that connect the paper's tables.

1. predictor-quality → JCT (links Table 2 to Table 5): sweep the predictor's
   relative error σ from oracle (0) to useless; shows how much predictor
   quality ISRTF actually needs (the paper's implicit claim is that
   R² ≈ 0.85 suffices — we map the whole curve).
2. MLFQ (FastServe-style) baseline — the paper's Table 1 design-space rival.
3. Anti-starvation aging: ISRTF's worst-case JCT with and without the aging
   term (paper §3.4 promises starvation prevention knobs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import NoisyOraclePredictor
from repro.core.metrics import improvement
from repro.simulate import ExperimentConfig, compare_policies, run_experiment
from repro.simulate.runner import make_predictor

from benchmarks.common import save_results


def predictor_quality_sweep(quick: bool = False):
    n_req = 100 if quick else 200
    base = ExperimentConfig(model="lam13", n_requests=n_req, batch_size=4,
                            rps_multiple=3.0, seed=21)
    fcfs = run_experiment(dataclasses.replace(base, policy="fcfs",
                                              predictor="none"))
    rows = []
    for sigma in (0.0, 0.25, 0.5, 1.0, 2.0):
        import repro.simulate.runner as R

        cfg = dataclasses.replace(base, policy="isrtf",
                                  predictor="noisy_oracle")
        # patch the predictor's noise level
        orig = R.make_predictor

        def patched(kind, seed=0, bge=None, _s=sigma, **_kw):
            if _s == 0.0:
                from repro.core import OraclePredictor

                return OraclePredictor()
            return NoisyOraclePredictor(sigma0=_s, decay=1.0, sigma_floor=_s,
                                        seed=seed)

        R.make_predictor = patched
        try:
            m = run_experiment(cfg)
        finally:
            R.make_predictor = orig
        rows.append({
            "sigma_rel": sigma,
            "isrtf_jct": round(m["jct_mean"], 2),
            "gain_vs_fcfs_pct": round(improvement(fcfs, m), 2),
        })
    rows.append({"fcfs_jct": round(fcfs["jct_mean"], 2)})
    return rows


def mlfq_comparison(quick: bool = False):
    n_req = 100 if quick else 200
    base = ExperimentConfig(model="lam13", n_requests=n_req, batch_size=4,
                            rps_multiple=3.0, seed=22)
    res = compare_policies(base, ("fcfs", "mlfq", "isrtf", "sjf"),
                           n_trials=2)
    return [{
        "policy": pol,
        "jct_mean": round(m["jct_mean"], 2),
        "gain_vs_fcfs_pct": round(improvement(res["fcfs"], m), 2),
    } for pol, m in res.items()]


def aging_ablation(quick: bool = False):
    n_req = 100 if quick else 200
    rows = []
    for aging in (0.0, 2.0, 10.0):
        cfg = ExperimentConfig(model="lam13", n_requests=n_req, batch_size=4,
                               rps_multiple=5.0, seed=23, policy="isrtf",
                               aging_rate=aging)
        m = run_experiment(cfg)
        rows.append({
            "aging_rate_tokens_per_s": aging,
            "jct_mean": round(m["jct_mean"], 2),
            "jct_p99": round(m["jct_p99"], 2),
            "jct_max": round(m["jct_max"], 2),
        })
    return rows


def repredict_stride_ablation(quick: bool = False):
    """Prediction staleness (SchedulerConfig.repredict_every): how much JCT
    does ISRTF give back when the encoder runs every N windows instead of
    every window (ALISE-style cached predictions decayed by progress)?"""
    n_req = 100 if quick else 200
    rows = []
    for stride in (1, 2, 4, 8):
        cfg = ExperimentConfig(model="lam13", n_requests=n_req, batch_size=4,
                               rps_multiple=3.0, seed=24, policy="isrtf",
                               repredict_every=stride)
        m = run_experiment(cfg)
        rows.append({
            "repredict_every": stride,
            "jct_mean": round(m["jct_mean"], 2),
            "jct_p99": round(m["jct_p99"], 2),
        })
    return rows


def run(quick: bool = False):
    rows = []
    rows += [{"ablation": "predictor_quality", **r}
             for r in predictor_quality_sweep(quick)]
    rows += [{"ablation": "mlfq_comparison", **r}
             for r in mlfq_comparison(quick)]
    rows += [{"ablation": "aging", **r} for r in aging_ablation(quick)]
    rows += [{"ablation": "repredict_stride", **r}
             for r in repredict_stride_ablation(quick)]
    save_results("ablations", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
