"""Pallas TPU kernels for the serving substrate's compute hot spots.

ELIS itself is a scheduling-layer contribution (no kernel in the paper); the
kernels here are the perf-critical layers of the serving substrate it drives:
prefill flash-attention, decode flash-attention (the decode_32k/long_500k hot
spot), and the Mamba2 SSD scan.  Each has a pure-jnp oracle in ``ref.py``.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
