"""Training objectives: next-token cross-entropy (+ MoE aux loss) for the
serving models, and the learning-to-rank losses for the length predictor's
ranking head (pairwise margin / listwise softmax over in-batch pools).

ISRTF only consumes the *order* of predicted remaining lengths, so a head
trained to rank (Fu et al., arXiv 2408.15792; Tao et al., arXiv 2510.03243)
can beat the point regressor at the scheduling objective even when its
magnitudes are useless — the two-head design in
:class:`repro.core.predictor.BGEPredictor` keeps the regression head for the
cluster layer's predicted-work accounting and trains this ranking head as a
sibling on the shared encoder trunk."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Mean masked token-level CE.  labels < 0 are also ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = mask & (labels >= 0)
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(jnp.where(mask, nll, 0.0)) / denom


def loss_fn(params, cfg, batch: Dict, *, attn_impl: str = "xla",
            moe_impl: str = "dense", remat: bool = False) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = T.forward(params, cfg, batch, attn_impl=attn_impl,
                            moe_impl=moe_impl, remat=remat)
    labels = batch["labels"]
    # VLM: stub patch positions carry no labels; logits cover [patches|text]
    if logits.shape[1] != labels.shape[1]:
        extra = logits.shape[1] - labels.shape[1]
        pad = jnp.full(labels.shape[:1] + (extra,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(labels, dtype=bool)
    elif mask.shape[1] != labels.shape[1]:
        extra = labels.shape[1] - mask.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros(mask.shape[:1] + (extra,), bool), mask], axis=1
        )
    ce = cross_entropy(logits, labels, mask)
    total = ce + cfg.moe.router_aux_weight * aux if cfg.moe.enabled else ce
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# learning-to-rank losses for the length predictor's ranking head
# ---------------------------------------------------------------------------

RANKING_LOSSES = ("pairwise", "listwise")
PAIR_SAMPLING = ("all", "same_step")


@dataclass(frozen=True)
class RankingConfig:
    """Configuration for the sibling ranking head on the BGE predictor.

    Presence of this config on :class:`repro.core.predictor.PredictorConfig`
    *enables* the second head; ``None`` (the default) keeps the predictor's
    parameter tree and traces bit-identical to the single-head model.
    ``margin`` is in log-token units (0.1 ≈ "10% longer should score
    higher"), matching the head's log-space output."""

    #: hinge margin for the pairwise loss, in log-token units
    margin: float = 0.1
    #: weight of the ranking loss relative to the regression Huber loss
    weight: float = 1.0
    #: "pairwise" margin hinge | "listwise" softmax cross-entropy
    loss: str = "pairwise"
    #: which in-batch pairs train the head: "all" | "same_step" (only
    #: compare requests observed at the same 50-token scheduling step, the
    #: comparison ISRTF actually makes)
    pair_sampling: str = "all"
    #: temperature on the log-label target distribution (listwise only)
    listwise_temperature: float = 1.0

    def __post_init__(self) -> None:
        if self.loss not in RANKING_LOSSES:
            raise ValueError(
                f"unknown ranking loss {self.loss!r} "
                f"(choose one of {RANKING_LOSSES})")
        if self.pair_sampling not in PAIR_SAMPLING:
            raise ValueError(
                f"unknown pair_sampling {self.pair_sampling!r} "
                f"(choose one of {PAIR_SAMPLING})")


def pairwise_margin_loss(scores: jnp.ndarray, log_labels: jnp.ndarray,
                         valid: jnp.ndarray, *, margin: float,
                         pair_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean hinge over all ordered in-batch pairs where label_i > label_j.

    ``scores`` and ``log_labels`` are (B,) in log space; the hinge wants
    score_i − score_j ≥ margin whenever request i truly runs longer than
    request j.  Ties contribute nothing.  ``valid`` masks padded rows and
    ``pair_mask`` optionally restricts which (i, j) pairs count."""
    sdiff = scores[:, None] - scores[None, :]
    want = (log_labels[:, None] - log_labels[None, :]) > 0.0
    pairs = valid[:, None] & valid[None, :] & want
    if pair_mask is not None:
        pairs = pairs & pair_mask
    hinge = jnp.maximum(margin - sdiff, 0.0)
    denom = jnp.maximum(jnp.sum(pairs), 1)
    return jnp.sum(jnp.where(pairs, hinge, 0.0)) / denom


def listwise_softmax_loss(scores: jnp.ndarray, log_labels: jnp.ndarray,
                          valid: jnp.ndarray, *,
                          temperature: float = 1.0) -> jnp.ndarray:
    """ListNet-style cross-entropy between the label and score distributions.

    The target is softmax(log_labels / T) over valid rows — longer requests
    get more probability mass — and the loss is its cross-entropy against
    log_softmax(scores)."""
    neg = jnp.float32(-1e9)
    target = jax.nn.softmax(jnp.where(valid, log_labels / temperature, neg))
    logp = jax.nn.log_softmax(jnp.where(valid, scores, neg))
    return -jnp.sum(jnp.where(valid, target * logp, 0.0))


def ranking_loss(cfg: RankingConfig, scores: jnp.ndarray, labels: jnp.ndarray,
                 valid: jnp.ndarray,
                 steps: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dispatch to the configured ranking loss.

    ``labels`` are raw remaining-token counts (compared in log space so the
    margin is scale-relative); ``steps`` is the per-row scheduling step used
    by ``pair_sampling="same_step"``."""
    log_labels = jnp.log(jnp.maximum(labels.astype(jnp.float32), 1.0))
    if cfg.loss == "listwise":
        return listwise_softmax_loss(
            scores, log_labels, valid, temperature=cfg.listwise_temperature)
    pair_mask = None
    if cfg.pair_sampling == "same_step" and steps is not None:
        pair_mask = steps[:, None] == steps[None, :]
    return pairwise_margin_loss(
        scores, log_labels, valid, margin=cfg.margin, pair_mask=pair_mask)
