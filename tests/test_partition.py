"""Partition rules: full leaf coverage + divisibility sanitation."""
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import partition as PT
from repro.launch.shapes import SHAPES, input_specs, supported
from repro.models import transformer as T


def fake_mesh(shape=(16, 16), names=("data", "model")):
    return SimpleNamespace(axis_names=names, devices=np.zeros(shape))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    ap = T.abstract_params(cfg)
    specs = PT.param_pspecs(cfg, ap)  # raises KeyError on uncovered leaves
    n_leaves = len(jax.tree_util.tree_leaves(ap))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_specs == n_leaves
    # ndim congruence
    for s, l in zip(
        jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_leaves(ap),
    ):
        assert len(s) <= l.ndim


@pytest.mark.parametrize("arch", sorted(list_archs()))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_cache_and_batch_specs_cover(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if not supported(cfg, sh):
        pytest.skip("documented skip")
    specs = input_specs(cfg, sh)
    if "cache" in specs:
        PT.cache_pspecs(cfg, specs["cache"], ("data",))
        PT.cache_pspecs(cfg, specs["cache"], ("data",), context_parallel=True)
    PT.batch_pspecs({k: v for k, v in specs.items() if k != "cache"},
                    ("data",))


def test_sanitize_drops_indivisible():
    mesh = fake_mesh()
    spec = P(None, "model")
    leaf = jax.ShapeDtypeStruct((4, 34), np.float32)  # 34 % 16 != 0
    out = PT.sanitize_specs(mesh, spec, leaf)
    assert out == P(None, None)
    leaf2 = jax.ShapeDtypeStruct((4, 32), np.float32)
    assert PT.sanitize_specs(mesh, spec, leaf2) == P(None, "model")


def test_sanitize_handles_axis_tuples():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = P(("pod", "data"), None)
    ok = jax.ShapeDtypeStruct((64, 8), np.float32)    # 64 % 32 == 0
    bad = jax.ShapeDtypeStruct((40, 8), np.float32)   # 40 % 32 != 0
    assert PT.sanitize_specs(mesh, spec, ok) == P(("pod", "data"), None)
    assert PT.sanitize_specs(mesh, spec, bad) == P(None, None)


def test_opt_pspecs_add_data_axis():
    mesh = fake_mesh()
    pspec = P(None, "model")
    leaf = jax.ShapeDtypeStruct((64, 32), np.float32)
    out = PT.opt_pspecs(mesh, pspec, leaf)
    assert out == P("data", "model")
    # already fully sharded dim is skipped; indivisible dims skipped
    leaf2 = jax.ShapeDtypeStruct((7, 32), np.float32)
    assert PT.opt_pspecs(mesh, pspec, leaf2) == P(None, "model")


@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_input_shapes_exact(shape_name):
    sh = SHAPES[shape_name]
    expect = {
        "train_4k": (4096, 256, "train"),
        "prefill_32k": (32768, 32, "prefill"),
        "decode_32k": (32768, 128, "decode"),
        "long_500k": (524288, 1, "decode"),
    }[shape_name]
    assert (sh.seq_len, sh.global_batch, sh.kind) == expect


def test_decode_shapes_are_one_token():
    for arch in ("yi-6b", "mamba2-130m", "zamba2-7b"):
        cfg = get_config(arch)
        specs = input_specs(cfg, SHAPES["decode_32k"])
        assert specs["tokens"].shape == (128, 1)


def test_long500k_window_carve_in():
    cfg = get_config("yi-6b")  # full attention -> sliding-window carve-in
    specs = input_specs(cfg, SHAPES["long_500k"])
    kv = specs["cache"]["kv"]
    assert kv.k.shape[2] <= 8192
    m = get_config("mamba2-130m")  # SSM: O(1) state, no KV at all
    specs = input_specs(m, SHAPES["long_500k"])
    assert "kv" not in specs["cache"]


def test_whisper_long500k_skip():
    cfg = get_config("whisper-large-v3")
    assert not supported(cfg, SHAPES["long_500k"])
    assert supported(cfg, SHAPES["decode_32k"])
