import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers the appropriate step (train_step / prefill_step / serve_step)
     with abstract inputs (ShapeDtypeStruct — zero allocation) and the
     partition rules from ``repro.launch.partition``,
  3. compiles it (SPMD — proves the sharding config is coherent),
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the optimized HLO) into experiments/dryrun/*.json —
     the §Roofline inputs.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch import partition as PT
from repro.launch import steps as ST
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.shapes import SHAPES, InputShape, input_specs, supported
from repro.models import transformer as T

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes of every collective op in the optimized HLO.

    HLO lines look like ``%ag = bf16[16,1024]{1,0} all-gather(...)`` (or a
    tuple ``= (bf16[..], bf16[..]) all-reduce(...)``); we account the output
    shapes, which equal the per-device bytes moved into the network for
    all-reduce and the received bytes for gather-style ops.
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("//"):
            continue
        m = re.search(r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)", stripped)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        if op + "-start" in stripped:
            pass  # async start carries the payload; done-ops parse to 0 anyway
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[op] += total
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _mem_dict(mem) -> Dict[str, float]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes")
    d = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            d[k] = float(v)
    if not d and isinstance(mem, dict):
        d = {k: float(v) for k, v in mem.items()}
    return d


def _cost_dict(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {k: float(v) for k, v in dict(cost).items()
            if isinstance(v, (int, float))}


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              *, moe_scheme: str = "tensor", remat: bool = True,
              extra_tag: str = "", cfg_override=None,
              save_record: bool = True, kv_dtype=None,
              kv_shard: str = "auto", params_data_sharded: bool = False,
              mesh_shape=None, attn_head_shard: bool = False) -> Dict:
    """Lower + compile one combination; returns the record dict.

    ``cfg_override``: substitute architecture config (cost probes lower
    reduced-layer unrolled variants with identical input shapes).
    §Perf knobs: ``kv_dtype="int8"`` (quantized cache), ``kv_shard``
    ("auto"|"seq"|"head_dim"|"heads"), ``params_data_sharded`` (ZeRO-3-style
    weight sharding for memory-bound decode), ``mesh_shape`` e.g. (8, 32).
    """
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    if not supported(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "long_500k unsupported (see DESIGN.md)"}

    if mesh_shape is not None:
        import numpy as _np
        from jax.sharding import Mesh as _Mesh

        devs = jax.devices()[: int(_np.prod(mesh_shape))]
        mesh = _Mesh(_np.asarray(devs).reshape(mesh_shape),
                     ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    bax = batch_axes(mesh)
    abstract_params = T.abstract_params(cfg)
    pspec = PT.param_pspecs(cfg, abstract_params, moe_scheme=moe_scheme)
    if params_data_sharded:
        pspec = PT.opt_pspecs(mesh, pspec, abstract_params)
    specs = input_specs(cfg, shape, kv_dtype=kv_dtype)

    t0 = time.time()
    import contextlib

    from repro.models.layers import attn_head_sharding

    hint = (attn_head_sharding("model") if attn_head_shard
            else contextlib.nullcontext())
    with mesh, hint:
        pspec = PT.sanitize_specs(mesh, pspec, abstract_params)
        if shape.kind == "train":
            step = ST.make_train_step(cfg, remat=remat)
            opt_abstract = ST.abstract_opt_state(abstract_params)
            # ZeRO-1: moments sharded over the data axes as well
            mspec = PT.opt_pspecs(mesh, pspec, abstract_params)
            opt_spec = ST.AdamWState(step=PT.P(), mu=mspec, nu=mspec)
            bspec = PT.batch_pspecs(specs, bax)
            bspec = PT.sanitize_specs(mesh, bspec, specs)
            lowered = jax.jit(
                step,
                in_shardings=(PT.shardings(mesh, pspec),
                              PT.shardings(mesh, opt_spec),
                              PT.shardings(mesh, bspec)),
                donate_argnums=(0, 1),
            ).lower(abstract_params, opt_abstract, specs)
        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg)
            cache_abstract = specs["cache"]
            msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            cspec = PT.cache_pspecs(cfg, cache_abstract, bax,
                                    model_size=msize, kv_shard=kv_shard)
            bspec = PT.batch_pspecs(
                {k: v for k, v in specs.items() if k != "cache"}, bax)
            bspec["cache"] = cspec
            bspec = PT.sanitize_specs(mesh, bspec, specs)
            lowered = jax.jit(
                step,
                in_shardings=(PT.shardings(mesh, pspec),
                              PT.shardings(mesh, bspec)),
            ).lower(abstract_params, specs)
        else:  # decode
            step = ST.make_serve_step(cfg)
            ctx_par = shape.global_batch < 16
            cache_abstract = specs["cache"]
            msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            cspec = PT.cache_pspecs(cfg, cache_abstract, bax,
                                    context_parallel=ctx_par,
                                    model_size=msize, kv_shard=kv_shard)
            cspec = PT.sanitize_specs(mesh, cspec, cache_abstract)
            tok_spec = PT.P(None if ctx_par else bax, None)
            tok = specs["tokens"]
            tok_spec = PT.sanitize_specs(mesh, tok_spec, tok)
            lowered = jax.jit(
                step,
                in_shardings=(PT.shardings(mesh, pspec),
                              PT.shardings(mesh, tok_spec),
                              PT.shardings(mesh, cspec)),
                donate_argnums=(2,),
            ).lower(abstract_params, tok, cache_abstract)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = _mem_dict(compiled.memory_analysis())
    cost = _cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": int(mesh.devices.size),
        "moe_scheme": moe_scheme,
        "remat": remat,
        "kv_dtype": kv_dtype,
        "kv_shard": kv_shard,
        "params_data_sharded": params_data_sharded,
        "attn_head_shard": attn_head_shard,
        "mesh_shape": list(mesh.devices.shape),
        "tag": extra_tag,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": {k: v for k, v in cost.items()
                          if k in ("flops", "bytes accessed",
                                   "bytes accessed operand 0",
                                   "bytes accessed output", "transcendentals",
                                   "optimal_seconds")},
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return rec


def save(rec: Dict, out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(list_archs()))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape)")
    ap.add_argument("--moe-scheme", default="tensor",
                    choices=["tensor", "expert"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-dtype", default=None, choices=[None, "int8"])
    ap.add_argument("--kv-shard", default="auto",
                    choices=["auto", "seq", "head_dim", "heads"])
    ap.add_argument("--params-data-sharded", action="store_true")
    ap.add_argument("--attn-head-shard", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 8,32")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)

    archs = list(list_archs()) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} × {shape} × {'multi' if mp else 'single'}"
                try:
                    rec = lower_one(arch, shape, mp,
                                    moe_scheme=args.moe_scheme,
                                    remat=not args.no_remat,
                                    extra_tag=args.tag,
                                    kv_dtype=args.kv_dtype,
                                    kv_shard=args.kv_shard,
                                    params_data_sharded=args.params_data_sharded,
                                    mesh_shape=mesh_shape,
                                    attn_head_shard=args.attn_head_shard)
                except Exception as e:  # noqa: BLE001 — report and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "tag": args.tag, "status": "error",
                           "error": repr(e)}
                    n_fail += 1
                path = save(rec, args.out)
                if rec["status"] == "ok":
                    print(f"OK   {label}: compile={rec['compile_s']}s "
                          f"flops={rec['flops']:.3e} "
                          f"coll={rec['collectives']['total']:.3e}B -> {path}")
                    print("     mem:", rec["memory_analysis"])
                elif rec["status"] == "skipped":
                    print(f"SKIP {label}: {rec['reason']}")
                else:
                    print(f"FAIL {label}: {rec['error']}")
    if n_fail:
        raise SystemExit(f"{n_fail} combinations failed")


if __name__ == "__main__":
    main()
