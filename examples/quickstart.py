"""Quickstart: serve a reduced model through the ELIS online API.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced qwen2-1.5b, submits a handful of prompts with bursty
(Gamma) arrivals through :class:`ElisServer`, streams one response chunk by
chunk, and prints per-request JCT under the ISRTF scheduler driving the
live JAX engine.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElisServer,
    FrontendConfig,
    OraclePredictor,
    Request,
    RequestOptions,
    SchedulerConfig,
    summarize,
)
from repro.data import GammaArrivals, HashTokenizer
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params


def main():
    cfg = get_config("qwen2-1.5b").reduced()
    print(f"model: {cfg.arch_id} ({cfg.n_layers}L d={cfg.d_model})")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=256, max_output=24, eos_id=-1,
        respect_job_max=True))

    server = ElisServer(
        FrontendConfig(n_nodes=1,
                       scheduler=SchedulerConfig(policy="isrtf", window=8,
                                                 batch_size=2)),
        OraclePredictor(),
        EngineExecutor({0: engine}),
    )

    tok = HashTokenizer()
    prompts = [
        ("what is the weather forecast", 8),
        ("write a long detailed story about a storm", 24),
        ("yes or no: is it raining", 6),
        ("explain how rain forms step by step", 16),
    ]
    rng = np.random.RandomState(0)
    arrivals = GammaArrivals().rate_scaled(2.0).sample_arrival_times(
        len(prompts), rng)
    handles = []
    for (text, length), t in zip(prompts, arrivals):
        handles.append(server.submit(Request(
            prompt=text, prompt_tokens=tok.encode(text),
            arrival_time=float(t), true_output_len=length,
            options=RequestOptions(max_tokens=length, stream=True))))

    # stream the first request token-chunk by token-chunk (this steps the
    # scheduler just far enough to produce each chunk)
    print("\nstreaming request 0:")
    for chunk in server.stream(handles[0]):
        tail = " (final)" if chunk.final else ""
        print(f"  t={chunk.t:6.2f}s iter {chunk.index}: "
              f"{len(chunk.tokens)} tokens{tail}")

    # then drain the rest of the system to completion
    responses = server.drain()
    print(f"\n{'req':>3s} {'len':>4s} {'JCT s':>8s} {'queue s':>8s}  prompt")
    for r, (text, _) in zip(responses, prompts):
        print(f"{r.request_id:3d} {r.n_tokens:4d} {r.jct():8.2f} "
              f"{r.queuing_delay:8.2f}  {text[:40]}")
    m = summarize(responses)
    print(f"\nmean JCT {m['jct_mean']:.2f}s; mean queuing delay "
          f"{m['queuing_delay_mean']:.2f}s; throughput {m['throughput_rps']:.2f} req/s")


if __name__ == "__main__":
    main()
