"""Paper Fig. 1: encoder CLS embeddings separate similar vs dissimilar topics.

100 same-topic (weather) vs 100 scattered-topic sentences; the paper shows
the former cluster tightly in PCA space.  We report the mean intra-cluster
distance of each set and their ratio (similar ≪ dissimilar)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import similarity_probe_sets
from repro.models.encoder import EncoderArchConfig, encode, init_encoder

from benchmarks.common import save_results


def run(quick: bool = False):
    n = 50 if quick else 100
    sim, dis, tok = similarity_probe_sets(n, seed=0)
    cfg = EncoderArchConfig(d_model=128, n_heads=4, n_layers=3, d_ff=256,
                            max_len=32)
    params = init_encoder(jax.random.PRNGKey(0), cfg)

    def embed(sentences):
        ml = 16
        toks = np.zeros((len(sentences), ml), np.int32)
        mask = np.zeros((len(sentences), ml), bool)
        for i, s in enumerate(sentences):
            ids = tok.encode(s, add_cls=True)[:ml]
            toks[i, : len(ids)] = ids
            mask[i, : len(ids)] = True
        cls, mean = encode(params, cfg, jnp.asarray(toks), jnp.asarray(mask))
        return np.asarray(cls)

    es, ed = embed(sim), embed(dis)
    intra_sim = float(np.linalg.norm(es - es.mean(0), axis=1).mean())
    intra_dis = float(np.linalg.norm(ed - ed.mean(0), axis=1).mean())
    rows = [{
        "n_sentences": n,
        "intra_cluster_dist_similar": round(intra_sim, 3),
        "intra_cluster_dist_dissimilar": round(intra_dis, 3),
        "separation_ratio": round(intra_dis / intra_sim, 3),
        "separable": intra_dis > intra_sim,
    }]
    save_results("fig1_embedding", rows)
    return rows


if __name__ == "__main__":
    print(run(quick=True))
