"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

TPU v5e hardware constants used by the roofline analysis live here too.
"""
from __future__ import annotations

import numpy as np

import jax

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run via "
            "repro.launch.dryrun (sets xla_force_host_platform_device_count)"
        )
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
