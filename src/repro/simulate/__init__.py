from repro.simulate.executor import SimExecutor
from repro.simulate.profiles import (
    PROFILES,
    SCHED_OVERHEAD_MS,
    ModelProfile,
    avg_request_rate,
)
from repro.simulate.runner import (
    ExperimentConfig,
    compare_policies,
    make_predictor,
    requests_to_jobs,
    run_experiment,
)

__all__ = [
    "ExperimentConfig",
    "ModelProfile",
    "PROFILES",
    "SCHED_OVERHEAD_MS",
    "SimExecutor",
    "avg_request_rate",
    "compare_policies",
    "make_predictor",
    "requests_to_jobs",
    "run_experiment",
]
