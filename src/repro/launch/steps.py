"""Step functions lowered by the dry-run (and runnable on real hardware).

  train_step(params, opt_state, batch) -> (params, opt_state, loss)
  prefill_step(params, batch_with_cache) -> (logits, cache)
  serve_step(params, tokens, cache) -> (next_token, cache)   # ONE new token
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.objective import loss_fn
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update

DRYRUN_OPT = AdamWConfig(lr=3e-4, schedule="cosine", warmup_steps=100,
                         total_steps=10_000)


def make_train_step(cfg, *, remat: bool = True, moe_impl: str = "dense",
                    opt_cfg: AdamWConfig = DRYRUN_OPT):
    def _loss(params, batch):
        return loss_fn(params, cfg, batch, moe_impl=moe_impl, remat=remat)

    def train_step(params, opt_state: AdamWState, batch: Dict):
        (l, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
            params, batch
        )
        params, opt_state, _ = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, l

    return train_step


def make_prefill_step(cfg, *, moe_impl: str = "dense"):
    def prefill_step(params, batch: Dict):
        batch = dict(batch)
        cache = batch.pop("cache")
        return T.prefill(params, cfg, batch, cache, moe_impl=moe_impl)

    return prefill_step


def make_serve_step(cfg, *, moe_impl: str = "dense"):
    """One-token decode; returns the sampled (greedy) token, not the logits,
    so the step's output footprint matches a real serving system."""

    def serve_step(params, tokens: jnp.ndarray, cache):
        logits, cache = T.decode_step(params, cfg, tokens, cache,
                                      moe_impl=moe_impl)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def abstract_opt_state(abstract_params) -> AdamWState:
    f32like = lambda t: jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), t
    )
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=f32like(abstract_params),
        nu=f32like(abstract_params),
    )
