"""Qwen2-1.5B [arXiv:2407.10671] — dense GQA with QKV bias.

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2-1.5b",
        family="dense",
        source="arXiv:2407.10671",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        attention_type="full",
        long_context_mode="sliding_window",
        max_position_embeddings=32768,
    )
)
