"""Shared helpers for the per-table/figure benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")


def save_results(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
