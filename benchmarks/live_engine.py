"""Live-engine evaluation: the fast path, measured.

Two studies on the real JAX engine (reduced model, wall-clock timed):

1. **Fast-path grid** — tokens/sec and per-window latency for
   ``attn_impl ∈ {xla, pallas}`` × ``mode ∈ {fast, serial}`` at several
   occupancies, where ``fast`` = batched bucketed prefill + masked
   (compacted) decode windows and ``serial`` = the pre-fast-path baseline
   (batch-1 prefills, full ``max_slots`` decode every window).  Asserts the
   fast path beats serial tokens/sec at ≥2 occupied slots and that the
   pallas and xla decode paths emit identical greedy tokens.  With ≥2
   devices the grid adds TP=2 cells: the ``shard_map``'d Pallas decode
   kernel runs under the mesh (``pallas_fallback is False`` asserted) and
   its greedy tokens must equal both the TP XLA path and the single-device
   Pallas fast path (docs/kernels.md, DESIGN.md §11).
2. **Policy comparison + live↔sim calibration** — ISRTF vs FCFS driven
   through the online :class:`ElisServer` API on an
   :class:`EngineExecutor`; the measured window log is fitted back onto the
   simulator's latency model (``EngineExecutor.calibrated_profile``) and
   the fitted profile is replayed in a :class:`SimExecutor` to report the
   live-vs-sim JCT gap.

Emits ``BENCH_live_engine.json`` at the repo root (committed).  ``--smoke``
runs the CI guard instead: one prefill compile per shape bucket, one decode
dispatch per window at the compacted batch bucket, frozen slots untouched,
pallas == xla numerics on a tiny config.

    PYTHONPATH=src python -m benchmarks.live_engine [--smoke|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElisServer,
    FrontendConfig,
    Job,
    OraclePredictor,
    PreemptionConfig,
    Request,
    RequestOptions,
    SchedulerConfig,
    summarize,
)
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_live_engine.json")


def _job(i: int, n_prompt: int) -> Job:
    toks = [10 + (7 * i + k) % 50 for k in range(n_prompt)]
    return Job(job_id=i, prompt=f"p{i}", prompt_tokens=toks, arrival_time=0.0)


# --------------------------------------------------------------------------- #
# Study 1: fast-path grid
# --------------------------------------------------------------------------- #


def _measure_variant(cfg, params, impl: str, fast: bool, occupancy: int,
                     max_slots: int, window: int, n_windows: int,
                     mesh=None) -> Dict:
    """Steady-state tokens/sec + per-window latency for one grid cell.

    The scenario is a *serve cycle* in the short-response churn regime —
    the LMSYS mode where most responses finish within a window, and the
    regime ELIS's iteration-level preemption creates on purpose
    (evict + recompute-on-resume): every window re-admits ``occupancy``
    jobs and decodes them to their cap.  Tokens/sec therefore prices
    admission (where batched prefill collapses N dispatches into one) AND
    decode (where masking compacts the dispatch to the occupied bucket).
    Warmup cycles pay all XLA compiles before timing starts.

    With ``mesh`` the cell runs tensor-parallel; ``impl="pallas"`` then
    exercises the mesh-aware shard_map'd decode kernel (DESIGN.md §11) —
    the cell asserts it really ran (``pallas_fallback is False``).
    """
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=max_slots, max_len=128, max_output=window, eos_id=-1,
        attn_impl=impl, batched_prefill=fast, masked_decode=fast,
        respect_job_max=False), mesh=mesh)
    if mesh is not None and impl == "pallas":
        assert eng.pallas_fallback is False, eng.pallas_fallback_reason
    next_id = [0]

    def fresh_batch():
        jobs = [_job(next_id[0] + i, 4 + ((next_id[0] + i) % 3))
                for i in range(occupancy)]
        next_id[0] += occupancy
        return jobs

    def cycle(jobs):
        """One serve cycle: admit (batched or serial), decode to the cap,
        evict the finished jobs (max_output == window ends each job in one
        window — the churn that puts prefill on the hot path)."""
        toks, fin = eng.run_window(jobs, window)
        for job, t in zip(jobs, toks):
            job.generated.extend(t)
        for job, f in zip(jobs, fin):
            if f or job.tokens_generated >= window:
                eng.evict_job(job.job_id)
        return toks

    warm = cycle(fresh_batch())             # pays prefill+decode compile
    sample_tokens = [t[:6] for t in warm[:2]]
    lat: List[float] = []
    tokens = 0
    for _ in range(n_windows):
        jobs = fresh_batch()
        t0 = time.perf_counter()
        toks = cycle(jobs)
        lat.append(time.perf_counter() - t0)
        tokens += sum(len(t) for t in toks)
    total = sum(lat)
    return {
        "attn_impl": impl, "mode": "fast" if fast else "serial",
        "tp": (1 if mesh is None
               else int(np.asarray(mesh.devices).size)),
        "occupancy": occupancy, "max_slots": max_slots, "window": window,
        "tokens_per_s": round(tokens / total, 2),
        "cycle_ms_median": round(float(np.median(lat)) * 1000, 2),
        "prefill_dispatches": eng.num_prefill_dispatches,
        "prefill_traces": eng.num_prefill_traces,
        "decode_dispatches": eng.num_decode_dispatches,
        "decode_traces": eng.num_decode_traces,
        "tokens": sample_tokens,
    }


def fast_path_grid(quick: bool) -> List[Dict]:
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_slots, window = 4, 8
    n_windows = 3 if quick else 6
    occupancies = (2,) if quick else (1, 2, 4)
    impls = ("xla", "pallas")
    rows = []
    for occ in occupancies:
        for impl in impls:
            for fast in (True, False):
                rows.append(_measure_variant(
                    cfg, params, impl, fast, occ, max_slots, window,
                    n_windows))
                print({k: v for k, v in rows[-1].items() if k != "tokens"})
    # pallas and xla greedy token streams must agree per (mode, occupancy)
    by = {(r["attn_impl"], r["mode"], r["occupancy"]): r for r in rows}
    for (impl, mode, occ), r in by.items():
        if impl == "pallas":
            ref = by[("xla", mode, occ)]
            assert r["tokens"] == ref["tokens"], \
                f"pallas!=xla tokens at mode={mode} occ={occ}"
    # the headline: fast beats serial at >= 2 occupied slots (xla path)
    for occ in occupancies:
        if occ < 2:
            continue
        f = by[("xla", "fast", occ)]
        s = by[("xla", "serial", occ)]
        assert f["tokens_per_s"] > s["tokens_per_s"], (
            f"fast path not faster at occupancy {occ}: "
            f"{f['tokens_per_s']} vs {s['tokens_per_s']} tok/s")

    # mesh cells: the TP2 pallas-vs-xla decode comparison (DESIGN.md §11).
    # On CPU the kernels run interpret=True, so this records the comparison
    # and pins TOKEN IDENTITY across {TP pallas, TP xla, single-device};
    # the perf win is a TPU claim, the identity contract is asserted here.
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,), ("model",), devices=jax.devices()[:2])
        tp_occs = occupancies if not quick else (2,)
        for occ in tp_occs:
            for impl in impls:
                rows.append(_measure_variant(
                    cfg, params, impl, True, occ, max_slots, window,
                    n_windows, mesh=mesh))
                print({k: v for k, v in rows[-1].items() if k != "tokens"})
        tp_by = {(r["attn_impl"], r["occupancy"]): r
                 for r in rows if r.get("tp", 1) > 1}
        for occ in tp_occs:
            p, x = tp_by[("pallas", occ)], tp_by[("xla", occ)]
            assert p["tokens"] == x["tokens"], \
                f"TP pallas != TP xla tokens at occ={occ}"
            assert p["tokens"] == by[("pallas", "fast", occ)]["tokens"], \
                f"TP pallas != single-device pallas tokens at occ={occ}"
    else:
        print("[live_engine] <2 devices: skipping the TP pallas-vs-xla "
              "cells (run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
    for r in rows:
        r.pop("tokens")
    return rows


# --------------------------------------------------------------------------- #
# Study 2: policy comparison + live<->sim calibration
# --------------------------------------------------------------------------- #


def _requests(n, seed, max_tokens=48):
    rng = np.random.RandomState(seed)
    reqs = []
    t = 0.0
    for i in range(n):
        # bimodal lengths: mostly short, some long (LMSYS-like skew)
        length = int(rng.choice([8, 12, 48], p=[0.5, 0.3, 0.2]))
        # near-burst arrivals tuned to the FAST engine's service rate
        # (~25 ms/window): the policy comparison is only meaningful under
        # sustained queue depth — with the pre-fast-path spacing (0.4 s
        # scale) the engine now drains jobs before a queue ever forms, and
        # the ISRTF-vs-FCFS gap degenerates to timing noise
        t += float(rng.gamma(0.73, 0.005))
        reqs.append(Request(
            prompt=f"p{i}", prompt_tokens=[10 + i % 50, 20, 30],
            arrival_time=t, true_output_len=length,
            # ground-truth stream: the live engine ignores it, but the
            # calibration replay's SimExecutor *replays* it — a job with an
            # empty stream would never progress in the simulator
            output_tokens=[1 + (37 * i + k) % 211 for k in range(length)],
            options=RequestOptions(max_tokens=max_tokens)))
    return reqs


def run(quick: bool = False):
    """ISRTF vs FCFS on the live engine + calibration of the sim profile."""
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = 12 if quick else 24
    rows = []
    executors = {}
    for policy in ("fcfs", "isrtf"):
        engine = InferenceEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=256, max_output=48, eos_id=-1,
            respect_job_max=True))
        # warm the prefill/decode shape buckets the study will hit, so the
        # measured JCTs (and the live<->sim calibration) reflect
        # steady-state service rather than XLA compile time
        w0, w1 = _job(9000, 3), _job(9001, 3)
        engine.add_jobs([w0, w1])               # (2, 16) prefill bucket
        engine.run_window([w0, w1], 8)          # (8, 2) decode shape
        engine.evict_job(w1.job_id)
        engine.evict_job(w0.job_id)
        w2 = _job(9002, 3)
        engine.add_jobs([w2])                   # (1, 16) prefill bucket
        engine.run_window([w2], 8)              # (8, 1) compacted decode
        engine.evict_job(w2.job_id)
        executor = EngineExecutor({0: engine})
        executors[policy] = executor
        server = ElisServer(
            FrontendConfig(
                n_nodes=1,
                scheduler=SchedulerConfig(policy=policy, window=8,
                                          batch_size=2),
                preemption=PreemptionConfig(enabled=policy != "fcfs"),
            ),
            OraclePredictor() if policy != "fcfs" else None,
            executor,
        )
        for r in _requests(n, seed=3):
            server.submit(r)
        done = server.drain()
        m = summarize(done)
        rows.append({"policy": policy, "n_jobs": len(done),
                     "jct_mean_s": round(m["jct_mean"], 3),
                     "queuing_delay_mean_s": round(m["queuing_delay_mean"], 3),
                     "preemptions": m["preemptions"],
                     "engine_counters": executor.counters()})
    imp = 100 * (rows[0]["jct_mean_s"] - rows[1]["jct_mean_s"]) / rows[0]["jct_mean_s"]
    rows.append({"live_isrtf_vs_fcfs_improvement_pct": round(imp, 2)})

    # live<->sim calibration: fit the simulator latency model to the
    # measured ISRTF window log, then replay the same workload in the
    # simulator under the fitted profile and report the JCT gap
    # calibration probes: the policy study only ever executes window=8, so
    # (overhead, rate) are collinear in its log — enrich with a second
    # window length and both batch widths to make the fit identifiable
    ex = executors["isrtf"]
    eng = ex.engines[0]
    pid = 9100
    for w in (4, 16):
        for batch in (1, 2):
            for _ in range(3):   # first occurrence per shape pays compile
                probes = [_job(pid + k, 3) for k in range(batch)]
                pid += batch
                ex.execute(0, probes, w, 0.0)
                for j in probes:
                    eng.evict_job(j.job_id)

    prof = ex.calibrated_profile(name="live-qwen2-reduced")
    overhead_s = ex.fit_overhead_s
    from repro.simulate import SimExecutor
    sim_server = ElisServer(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy="isrtf", window=8, batch_size=2),
            preemption=PreemptionConfig(enabled=True),
        ),
        OraclePredictor(),
        SimExecutor(prof, sched_overhead_s=overhead_s),
    )
    for r in _requests(n, seed=3):
        sim_server.submit(r)
    sim_done = sim_server.drain()
    sim_m = summarize(sim_done)
    live_jct = rows[1]["jct_mean_s"]
    rows.append({
        "calibration": {
            "decode_ms_1": round(prof.decode_ms_1, 3),
            "batch_slowdown": round(prof.batch_slowdown, 4),
            "window_overhead_ms": round(overhead_s * 1000, 3),
            "sim_jct_mean_s_with_fitted_profile": round(sim_m["jct_mean"], 3),
            "live_jct_mean_s": live_jct,
            "live_vs_sim_ratio": round(sim_m["jct_mean"] / max(live_jct, 1e-9), 3),
        }})
    save_results("live_engine", rows)
    return rows


# --------------------------------------------------------------------------- #
# CI smoke guard
# --------------------------------------------------------------------------- #


def smoke() -> None:
    """Assert the fast-path invariants on a tiny config (CI guard)."""
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=4, max_len=64, max_output=64, eos_id=-1))
    # two admission rounds hitting two distinct shape buckets
    j0, j1 = _job(0, 4), _job(1, 6)
    eng.add_jobs([j0, j1])                          # (2, 16) bucket
    j2 = _job(2, 20)
    eng.add_jobs([j2])                              # (1, 32) bucket
    assert eng.num_prefill_dispatches == 2, eng.num_prefill_dispatches
    assert eng.num_prefill_traces == 2, eng.num_prefill_traces
    assert eng.num_prefill_traces <= eng.prefill_shape_bound()
    # re-admitting the same shape must not retrace
    eng.evict_job(2)
    eng.add_jobs([_job(3, 18)])                     # (1, 32) again
    assert eng.num_prefill_traces == 2, "shape bucket retraced"

    # masked decode: dispatch count == windows, batch compacted to bucket
    toks, _ = eng.run_window([j0, j1], 4)
    assert eng.num_decode_dispatches == 1
    assert (4, 2) in eng._window_cache, list(eng._window_cache)
    # one window length in play -> decode traces bounded by the batch
    # buckets compaction can dispatch
    assert eng.num_decode_traces <= eng.decode_batch_buckets()
    frozen = np.asarray(eng.cache["len"])[eng.slot_of[3]]
    for job, t in zip((j0, j1), toks):
        job.generated.extend(t)
    eng.run_window([j0, j1], 4)
    assert eng.num_decode_dispatches == 2
    assert eng.num_decode_traces == 1, "decode shape retraced"
    # the unscheduled occupied slot stayed bit-frozen
    assert np.asarray(eng.cache["len"])[eng.slot_of[3]] == frozen

    # pallas == xla greedy numerics
    outs = {}
    for impl in ("xla", "pallas"):
        e = InferenceEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=64, max_output=64, eos_id=-1,
            attn_impl=impl))
        outs[impl], _ = e.run_window([_job(7, 5), _job(8, 3)], 6)
    assert outs["xla"] == outs["pallas"], "pallas decode diverges from xla"

    # pallas under a mesh: with >=2 devices the shard_map'd decode kernel
    # must actually run (no fallback) and emit the same tokens (the CI
    # pallas-under-mesh guard runs this smoke with 8 forced host devices)
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_mesh
        e = InferenceEngine(
            cfg, params,
            EngineConfig(max_slots=2, max_len=64, max_output=64, eos_id=-1,
                         attn_impl="pallas"),
            mesh=make_mesh((2,), ("model",), devices=jax.devices()[:2]))
        assert e.pallas_fallback is False, e.pallas_fallback_reason
        assert e.cfg.attn_impl == "pallas"
        tp_out, _ = e.run_window([_job(7, 5), _job(8, 3)], 6)
        assert tp_out == outs["xla"], "TP pallas decode diverges"
        mesh_note = "TP pallas==xla"
    else:
        mesh_note = "TP cells skipped (<2 devices)"
    print("live_engine smoke: OK (prefill buckets, masked decode, "
          f"pallas==xla, {mesh_note})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: fast-path invariants on a tiny config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        rows = fast_path_grid(quick=args.quick)
        rows += run(quick=args.quick)
        for r in rows:
            print(r)
        if not args.quick:
            with open(ROOT_JSON, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"wrote {ROOT_JSON}")
