"""Predictor-calibration sweep: {raw, EMA-debiased, conformal} x
{mean, q0.7, q0.9} risk levels under the bursty arrival regime.

The paper's scheduling gain rests on the response-length predictor; this
benchmark quantifies what the distribution-aware predictor API adds (PR 5's
``LengthPredictor`` subsystem) in three regimes, all under flash-crowd
bursts at high load (the regime where ranking mistakes cost JCT):

* ``regime="noisy_oracle"`` — the Fig. 2(b)-calibrated error model:
  unbiased but *step-heteroscedastic* (fresh jobs are predicted much more
  noisily than deep ones).  This is where risk-aware ranking has real
  leverage: an upper quantile inflates uncertain fresh predictions more
  than confident deep ones, hedging against the underestimates that cause
  head-of-line blocking.  Asserted: some risk level beats mean-ranking on
  mean or p99 JCT (measured: q0.7 ~ -2% mean / -3% p99 over 5 seeds).
* ``regime="biased_oracle"`` — the same oracle with a synthetic 0.4x
  multiplicative bias (systematic underestimates).  ISRTF *ordering* is
  scale-invariant, so JCT barely moves by construction — this regime
  documents the feedback loop itself.  Asserted: EMA debiasing drives the
  served bias toward 1 and cuts prediction MAE.
* ``regime="bge"`` — a briefly trained scratch BGE, the paper's model
  class.  Its fit-time *per-step* residual ladder (Fig. 2(b):
  step-dependent spread) makes risk quantiles available with no serving
  feedback at all, and they re-order fresh-vs-deep jobs exactly like the
  noisy-oracle regime.  Asserted (the acceptance bar): at least one
  calibrated configuration improves mean or p99 JCT over the raw point
  estimate (measured: raw q0.7 improves both, thinly — a regressor's
  errors are persistent per job, so hedging only fixes the cross-step
  component).  Honestly documented: per-step EMA debiasing *worsens*
  per-request MAE here — serving-time feedback is window-weighted (long
  jobs re-predict every window while they wait), so it optimises a
  different distribution than the per-request one; the committed JSON
  keeps those cells as the cautionary rows.

A standalone coverage probe additionally reports the conformal wrapper's
empirical quantile coverage on held-out requests (distribution-free
guarantee: >= q up to sampling slack).  All cells use fixed seed lists, so
every assertion is deterministic — a guard, not a coin flip.

Emits ``BENCH_predictor_calibration.json`` at the repo root (committed).
``--smoke`` runs the biased-oracle regime + coverage probe only, with the
bias/MAE/coverage assertions — the CI guard for the feedback loop.

    PYTHONPATH=src python -m benchmarks.predictor_calibration [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    BGEPredictor,
    CalibrationConfig,
    ConformalPredictor,
    Job,
    JobState,
    NoisyOraclePredictor,
    PredictorConfig,
)
from repro.data import make_predictor_dataset
from repro.models.encoder import EncoderArchConfig
from repro.simulate import ExperimentConfig, run_experiment

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_predictor_calibration.json")

CALIBRATIONS = ("none", "ema", "conformal", "ema+conformal")
RISKS = (None, 0.7, 0.9)

#: synthetic multiplicative bias for the controlled regime (underestimates)
BIAS = 0.4


def train_bge(seed: int = 0, num_steps: int = 120) -> BGEPredictor:
    """A deliberately small/briefly trained BGE — structurally the paper's
    predictor, imperfect enough that calibration has something to fix
    (at 120 steps the per-step residual spread is ~0.68 at step 0 falling
    to ~0.48 deep, the Fig. 2(b)-shaped heteroscedasticity that risk
    quantiles act on; a 350-step model is already too calibrated for
    serving-time correction to move JCT)."""
    cfg = PredictorConfig(
        encoder=EncoderArchConfig(d_model=64, n_heads=2, n_layers=2,
                                  d_ff=128, max_len=128),
        n_fc_layers=4, fc_hidden=128, max_len=128, lr=3e-4,
    )
    pred = BGEPredictor(cfg, seed=seed)
    tr, _, _ = make_predictor_dataset(500, seed=seed, max_len=128,
                                      max_steps=4)
    pred.fit(tr, num_steps=num_steps, batch_size=32)
    return pred


def one_cell(regime: str, calibrate: str, risk: Optional[float],
             n_requests: int, seeds: List[int], bge=None) -> Dict:
    """One sweep cell under bursty arrivals, averaged over seeds."""
    agg = {"jct_mean": [], "jct_p99": [], "pred_mae": [], "pred_bias": []}
    for seed in seeds:
        cfg = ExperimentConfig(
            model="vic", policy="isrtf",
            predictor={"noisy_oracle": "noisy_oracle",
                       "biased_oracle": "noisy_oracle",
                       "bge": "bge",
                       "oracle": "oracle"}[regime],
            predictor_bias=BIAS if regime == "biased_oracle" else 1.0,
            calibrate=calibrate, risk_quantile=risk,
            n_requests=n_requests, batch_size=4, rps_multiple=1.5,
            seed=seed, arrivals="bursty", burst_size=24,
        )
        # streaming aggregation keeps peak memory flat across the sweep
        # (means/MAE/bias exact; p99 within the sketch's ~0.3% tolerance)
        m = run_experiment(cfg, bge=bge, stream_metrics=True)
        assert m["n_unfinished"] == 0, m
        agg["jct_mean"].append(m["jct_mean"])
        agg["jct_p99"].append(m["jct_p99"])
        agg["pred_mae"].append(m.get("pred_mae_mean", float("nan")))
        agg["pred_bias"].append(m.get("pred_bias_gmean", float("nan")))
    return {
        "regime": regime,
        "calibrate": calibrate,
        "risk_quantile": risk,
        "n_requests": n_requests,
        "seeds": seeds,
        "jct_mean": round(float(np.mean(agg["jct_mean"])), 3),
        "jct_p99": round(float(np.mean(agg["jct_p99"])), 3),
        "pred_mae": round(float(np.mean(agg["pred_mae"])), 2),
        "pred_bias": round(float(np.mean(agg["pred_bias"])), 4),
    }


def cell(rows: List[Dict], **want) -> Optional[Dict]:
    for r in rows:
        if all(r.get(k) == v for k, v in want.items()):
            return r
    return None


def coverage_probe(n_cal: int = 600, n_test: int = 300,
                   seed: int = 0) -> Dict:
    """Empirical coverage of the conformal wrapper's q-quantiles on
    held-out requests (outside the scheduler, so coverage is measured on
    clean exchangeable residuals)."""
    rng = np.random.RandomState(seed)
    wrapped = ConformalPredictor(
        NoisyOraclePredictor(seed=seed + 1, bias=BIAS),
        CalibrationConfig(conformal=True, window=2 * n_cal,
                          min_samples=30, by_step=False))

    def mk(jid, L):
        return Job(job_id=jid, prompt="p", prompt_tokens=[1],
                   arrival_time=0.0, true_output_len=L)

    for i in range(n_cal):
        L = int(rng.randint(20, 500))
        j = mk(i, L)
        wrapped.predict([j])
        j.generated = [7] * L
        j.state = JobState.FINISHED
        wrapped.observe(j, 0.0)
    out = {"probe": "conformal_coverage", "n_cal": n_cal, "n_test": n_test}
    for q in (0.7, 0.9):
        covered = 0
        for i in range(n_test):
            L = int(rng.randint(20, 500))
            [p] = wrapped.predict([mk(10_000 + i, L)])
            if p.quantile(q) >= L:
                covered += 1
        out[f"coverage_q{q}"] = round(covered / n_test, 4)
        slack = 3.5 * math.sqrt(q * (1 - q)) * math.sqrt(
            1.0 / n_cal + 1.0 / n_test)
        assert covered / n_test >= q - slack, (
            f"conformal q={q} coverage {covered / n_test:.3f} "
            f"below {q} - {slack:.3f}")
    return out


def _calibrated(rows: List[Dict], regime: str) -> List[Dict]:
    """Every sweep cell of ``regime`` except the raw point estimate."""
    return [r for r in rows if r.get("regime") == regime
            and not (r["calibrate"] == "none" and r["risk_quantile"] is None)]


def run(smoke: bool = False, quick: bool = False) -> List[Dict]:
    smoke = smoke or quick  # benchmarks.run harness passes quick=
    if smoke:
        n_requests, seeds = 80, [0, 1]
        regimes = ["biased_oracle"]
    else:
        n_requests, seeds = 150, [0, 1, 2]
        regimes = ["noisy_oracle", "biased_oracle", "bge"]

    rows: List[Dict] = [coverage_probe()]
    bge = train_bge() if "bge" in regimes else None
    #: oracle reference (the ideal bound; identical for every regime)
    rows.append(one_cell("oracle", "none", None, n_requests, seeds))
    for regime in regimes:
        for calibrate in CALIBRATIONS:
            for risk in RISKS:
                rows.append(one_cell(regime, calibrate, risk,
                                     n_requests, seeds, bge=bge))
                print(rows[-1], flush=True)

    # -- hard guarantees the committed JSON documents (fixed seeds, so
    #    each is deterministic: a regression guard, not a coin flip) ----- #
    # 1. the feedback loop works: under a systematically biased predictor,
    #    EMA debiasing pulls the served bias toward 1 and cuts MAE
    raw = cell(rows, regime="biased_oracle", calibrate="none",
               risk_quantile=None)
    ema = cell(rows, regime="biased_oracle", calibrate="ema",
               risk_quantile=None)
    assert abs(math.log(ema["pred_bias"])) \
        < abs(math.log(raw["pred_bias"])), (raw, ema)
    assert ema["pred_mae"] < raw["pred_mae"], (raw, ema)
    if not smoke:
        # 2. risk-aware ranking has real leverage under step-heteroscedastic
        #    errors: some upper-quantile cell beats mean-ranking JCT
        raw = cell(rows, regime="noisy_oracle", calibrate="none",
                   risk_quantile=None)
        hedged = _calibrated(rows, "noisy_oracle")
        assert min(r["jct_mean"] for r in hedged) < raw["jct_mean"] \
            or min(r["jct_p99"] for r in hedged) < raw["jct_p99"], (
            f"risk hedging never beat mean-ranking: raw={raw}")
        # 3. the acceptance bar: some calibrated configuration beats the
        #    raw BGE point estimate on mean or p99 JCT under bursty load
        raw = cell(rows, regime="bge", calibrate="none", risk_quantile=None)
        calibrated = _calibrated(rows, "bge")
        best_mean = min(r["jct_mean"] for r in calibrated)
        best_p99 = min(r["jct_p99"] for r in calibrated)
        assert best_mean < raw["jct_mean"] or best_p99 < raw["jct_p99"], (
            f"no calibrated configuration improved on raw BGE: "
            f"raw={raw}, best_mean={best_mean}, best_p99={best_p99}")

    save_results("predictor_calibration", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="biased-oracle regime + coverage probe only "
                         "(CI feedback-loop guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(smoke=args.smoke and not args.full)
    if not args.smoke:
        # regenerate the committed evidence only on a deliberate CLI run
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    oracle = cell(rows, regime="oracle")
    for regime in sorted({r["regime"] for r in rows if "calibrate" in r}):
        if regime == "oracle":
            continue
        raw = cell(rows, regime=regime, calibrate="none", risk_quantile=None)
        best = min((r for r in rows if r.get("regime") == regime),
                   key=lambda r: r["jct_mean"])
        gap = raw["jct_mean"] - oracle["jct_mean"]
        closed = raw["jct_mean"] - best["jct_mean"]
        print(f"[predictor_calibration] {regime}: raw {raw['jct_mean']:.2f}s "
              f"-> best {best['calibrate']}/q={best['risk_quantile']} "
              f"{best['jct_mean']:.2f}s (oracle {oracle['jct_mean']:.2f}s; "
              f"{100 * closed / gap if gap > 0 else 0:.0f}% of gap closed); "
              f"bias {raw['pred_bias']:.2f} -> "
              f"{cell(rows, regime=regime, calibrate='ema', risk_quantile=None)['pred_bias']:.2f}")


if __name__ == "__main__":
    main()
