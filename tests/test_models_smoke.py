"""Deliverable (f): per-arch reduced smoke — one forward/train step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import forward, init_params, loss_fn
from repro.training import AdamWConfig, adamw_init, adamw_update

B, S = 2, 32


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model)
        )
        batch["labels"] = jax.random.randint(
            key, (B, S + cfg.frontend_tokens), 0, cfg.vocab_size
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, cfg, batch)
    exp_s = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    opt_state = adamw_init(params)

    def loss(p, b):
        return loss_fn(p, cfg, b)

    (l0, _), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
    assert np.isfinite(float(l0))
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    params2, opt_state, _ = adamw_update(opt_cfg, grads, opt_state, params)
    (l1, _), _ = jax.value_and_grad(loss, has_aux=True)(params2, batch)
    assert np.isfinite(float(l1))
    # one step on the same batch should not increase the loss (lr small)
    assert float(l1) <= float(l0) + 1e-3


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x7b", "mamba2-130m"])
def test_remat_matches_no_remat(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    l0, _ = loss_fn(params, cfg, batch, remat=False)
    l1, _ = loss_fn(params, cfg, batch, remat=True)
    assert abs(float(l0) - float(l1)) < 1e-5
