"""BGE-style bidirectional transformer encoder (the predictor backbone).

Mirrors BAAI/bge-base-en-v1.5 structurally: token + learned position
embeddings, post-LN transformer encoder layers (MHA, GELU MLP), CLS token at
position 0, mean pooling over valid tokens.  Scaled down by default for CPU
training — the architecture class (frozen encoder + FC head) is what the paper
relies on, not the 110M-parameter checkpoint (see DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclass(frozen=True)
class EncoderArchConfig:
    vocab_size: int = 8192
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_len: int = 512
    dtype: str = "float32"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


#: full-size variant matching bge-base-en-v1.5 (for the dry-run / docs)
BGE_BASE = EncoderArchConfig(
    vocab_size=30522, d_model=768, n_heads=12, n_layers=12, d_ff=3072,
    max_len=512,
)


def init_encoder(key, cfg: EncoderArchConfig) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)

    def layer_init(k):
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        d = cfg.d_model
        return {
            "wq": L.dense_init(k1, d, d, dtype),
            "wk": L.dense_init(k2, d, d, dtype),
            "wv": L.dense_init(k3, d, d, dtype),
            "wo": L.dense_init(k4, d, d, dtype),
            "attn_norm": L.init_layernorm(d, dtype),
            "mlp": L.init_mlp(k5, d, cfg.d_ff, False, dtype),
            "mlp_norm": L.init_layernorm(d, dtype),
        }

    keys = jax.random.split(ks[2], cfg.n_layers)
    return {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "pos": L.embed_init(ks[1], cfg.max_len, cfg.d_model, dtype),
        "layers": jax.vmap(layer_init)(keys),
        "final_norm": L.init_layernorm(cfg.d_model, dtype),
    }


def encode(params: Dict, cfg: EncoderArchConfig, tokens: jnp.ndarray,
           mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) int32, mask (B, S) bool ->
    (cls (B, d), mean_pooled (B, d))."""
    b, s = tokens.shape
    h = params["embed"][tokens] + params["pos"][None, :s]

    def body(x, lp):
        hh = x
        q = (hh @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (hh @ lp["wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        v = (hh @ lp["wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.head_dim)
        )
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = L.layernorm(lp["attn_norm"], x + out @ lp["wo"])  # post-LN
        mlp_out = jax.nn.gelu(x @ lp["mlp"]["w_up"]) @ lp["mlp"]["w_down"]
        x = L.layernorm(lp["mlp_norm"], x + mlp_out)
        return x, None

    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.layernorm(params["final_norm"], h)
    cls = h[:, 0, :]
    m = mask[..., None].astype(h.dtype)
    mean = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return cls, mean
