"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

VLM: the ViT vision encoder + projector is a STUB per the repro spec —
``input_specs`` provides pre-projected patch embeddings of shape
``(batch, frontend_tokens, d_model)`` which the model interleaves with text
embeddings.  The transformer backbone below is exact: 28L, d_model 3584,
28 heads (GQA kv=4), d_ff 18944, vocab 152064, M-RoPE with sections
(16, 24, 24) over the 64-dim rotary half.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2-vl-7b",
        family="vlm",
        source="arXiv:2409.12191",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_type="mrope",
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        frontend="vision_stub",
        frontend_tokens=256,
        attention_type="full",
        long_context_mode="sliding_window",
        max_position_embeddings=32768,
    )
)
