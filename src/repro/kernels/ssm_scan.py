"""Pallas chunked-SSD scan kernel (Mamba2).

TPU adaptation of the SSD algorithm (arXiv:2405.21060): within a chunk the
recurrence is a pair of small dense matmuls (MXU work); across chunks a
(P × N) state is carried in VMEM scratch through the sequential trailing grid
axis — no CUDA selective-scan, no inter-block synchronisation.

  grid = (batch, heads, num_chunks)
  per step:  y_diag = (C B^T ∘ L) x        (intra-chunk, lower-triangular L)
             y_off  = exp(a_cum) · C h_in  (inter-chunk via carried state)
             h_out  = exp(a_cum[-1]) h_in + (decay ∘ B)^T x

Inputs are pre-scaled (x ← x·dt, a ← dt·A) as in the model layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, a_ref, b_ref, c_ref, y_ref, fs_ref,
    state_ref,
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (C, P)
    a = a_ref[0, :, 0].astype(jnp.float32)     # (C,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (C, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (C, N)

    a_cum = jnp.cumsum(a)  # (C,)
    # segsum: seg[t, s] = sum_{s < r <= t} a[r] for s <= t
    seg = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = row >= col
    L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)

    # intra-chunk
    scores = jnp.dot(cm, bm.T) * L          # (C, C)
    y = jnp.dot(scores, x)                  # (C, P)

    # inter-chunk
    h_in = state_ref[...]                    # (P, N)
    y += jnp.exp(a_cum)[:, None] * jnp.dot(cm, h_in.T)

    # state carry
    decay_states = jnp.exp(a_cum[-1] - a_cum)          # (C,)
    h_out = h_in * jnp.exp(a_cum[-1]) + jnp.dot(
        (x * decay_states[:, None]).T, bm
    )  # (P, N)
    state_ref[...] = h_out

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        fs_ref[0, 0, :, :] = h_out.astype(fs_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,   # (B, S, H, P) pre-multiplied by dt
    a: jnp.ndarray,   # (B, S, H)    log decay = dt * A
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    grid = (b, h, nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ci: (b_, ci, h_)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ci: (b_, ci, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm)
    return y, fs
