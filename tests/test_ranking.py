"""Learning-to-rank subsystem: ranking losses, the two-head BGE predictor,
rank-aware ISRTF ordering (``SchedulerConfig.rank_by``), Kendall-τ, the
``RankedPredictor`` online feedback loop (censoring + deterministic pair
harvesting), and the guarantee that rank scores NEVER leak into the
cluster layer's predicted-work accounting."""
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BGEPredictor,
    CalibrationConfig,
    ConformalPredictor,
    EMADebiasedPredictor,
    Job,
    JobState,
    LengthPrediction,
    LengthPredictor,
    OraclePredictor,
    PredictorConfig,
    RankedPredictor,
    RankingConfig,
    SchedulerConfig,
    kendall_tau,
    make_policy,
    make_predictor,
)
from repro.core.scheduler import RANK_BY, score_jobs
from repro.models.encoder import EncoderArchConfig
from repro.models.objective import (
    listwise_softmax_loss,
    pairwise_margin_loss,
    ranking_loss,
)


def mk_job(jid, true_len=100, arrival=0.0, generated=0, prompt_tokens=None):
    j = Job(job_id=jid, prompt=f"p{jid}",
            prompt_tokens=prompt_tokens or [1, 2, 3],
            arrival_time=arrival, true_output_len=true_len)
    j.generated = [7] * generated
    return j


def tiny_cfg(ranking=None):
    return PredictorConfig(
        encoder=EncoderArchConfig(d_model=16, n_heads=2, n_layers=1,
                                  d_ff=32, max_len=32),
        n_fc_layers=2, fc_hidden=16, max_len=32, ranking=ranking)


def trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


class FakeRankPredictor(LengthPredictor):
    """Deterministic two-head stand-in: mean and rank_score per job id."""

    def __init__(self, means, ranks):
        self.means = means
        self.ranks = ranks

    def predict(self, jobs):
        return [LengthPrediction(mean=float(self.means[j.job_id]),
                                 rank_score=float(self.ranks[j.job_id]))
                for j in jobs]


# --------------------------------------------------------------------------- #
# RankingConfig + loss functions
# --------------------------------------------------------------------------- #


class TestRankingLosses:
    def test_config_rejects_unknown_loss(self):
        with pytest.raises(ValueError, match=r"listwise"):
            RankingConfig(loss="hinge^2")

    def test_config_rejects_unknown_pair_sampling(self):
        with pytest.raises(ValueError, match=r"same_step"):
            RankingConfig(pair_sampling="adjacent")

    def test_pairwise_zero_when_ordering_respected_with_margin(self):
        scores = np.array([2.0, 1.0, 0.0], np.float32)
        log_labels = np.array([3.0, 2.0, 1.0], np.float32)
        valid = np.array([True, True, True])
        loss = pairwise_margin_loss(scores, log_labels, valid, margin=0.5)
        assert float(loss) == pytest.approx(0.0, abs=1e-7)

    def test_pairwise_penalises_inverted_ordering(self):
        scores = np.array([0.0, 1.0, 2.0], np.float32)
        log_labels = np.array([3.0, 2.0, 1.0], np.float32)
        valid = np.array([True, True, True])
        loss = pairwise_margin_loss(scores, log_labels, valid, margin=0.5)
        # hinges: pairs (0,1),(1,2) violated by 1 + margin, (0,2) by 2 +
        # margin -> mean (1.5 + 2.5 + 1.5) / 3
        assert float(loss) == pytest.approx(5.5 / 3, abs=1e-6)

    def test_pairwise_ignores_invalid_rows_and_ties(self):
        scores = np.array([0.0, 5.0, -3.0], np.float32)
        log_labels = np.array([2.0, 2.0, 9.0], np.float32)
        valid = np.array([True, True, False])
        # rows 0/1 tie on label, row 2 is padding -> no pairs at all
        loss = pairwise_margin_loss(scores, log_labels, valid, margin=0.5)
        assert float(loss) == pytest.approx(0.0, abs=1e-7)

    def test_listwise_prefers_aligned_scores(self):
        log_labels = np.array([3.0, 2.0, 1.0], np.float32)
        valid = np.array([True, True, True])
        aligned = listwise_softmax_loss(
            np.array([3.0, 2.0, 1.0], np.float32), log_labels, valid)
        inverted = listwise_softmax_loss(
            np.array([1.0, 2.0, 3.0], np.float32), log_labels, valid)
        assert float(aligned) < float(inverted)

    def test_ranking_loss_same_step_masks_cross_step_pairs(self):
        cfg = RankingConfig(pair_sampling="same_step", margin=0.1)
        scores = np.array([1.0, 0.0, 5.0], np.float32)
        labels = np.array([100.0, 10.0, 1.0], np.float32)
        valid = np.array([True, True, True])
        steps = np.array([0, 0, 1], np.int32)
        masked = ranking_loss(cfg, scores, labels, valid, steps=steps)
        # only the (0, 1) same-step pair counts and it is satisfied
        assert float(masked) == pytest.approx(0.0, abs=1e-7)
        allpairs = ranking_loss(RankingConfig(margin=0.1), scores, labels,
                                valid, steps=steps)
        # cross-step pairs (0,2) and (1,2) are badly violated
        assert float(allpairs) > 1.0

    def test_listwise_dispatch(self):
        cfg = RankingConfig(loss="listwise", listwise_temperature=2.0)
        scores = np.array([1.0, 2.0], np.float32)
        labels = np.array([10.0, 100.0], np.float32)
        valid = np.array([True, True])
        got = ranking_loss(cfg, scores, labels, valid)
        want = listwise_softmax_loss(
            scores, np.log(labels), valid, temperature=2.0)
        assert float(got) == pytest.approx(float(want), abs=1e-6)


# --------------------------------------------------------------------------- #
# Kendall-τ
# --------------------------------------------------------------------------- #


class TestKendallTau:
    def test_perfect_and_inverted(self):
        assert kendall_tau([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
        assert kendall_tau([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)

    def test_tau_b_tie_correction(self):
        # P=4, Q=0, Tx=2, Ty=0 -> 4 / sqrt(6 * 4)
        got = kendall_tau([1, 1, 2, 2], [1, 2, 3, 4])
        assert got == pytest.approx(4 / math.sqrt(24), abs=1e-9)

    def test_degenerate_inputs(self):
        assert kendall_tau([], []) == 0.0
        assert kendall_tau([5], [3]) == 0.0
        assert kendall_tau([2, 2, 2], [1, 2, 3]) == 0.0

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError, match=r"length"):
            kendall_tau([1, 2], [1, 2, 3])


# --------------------------------------------------------------------------- #
# Two-head BGE predictor
# --------------------------------------------------------------------------- #


class TestTwoHeadBGE:
    def test_param_tree_identical_with_ranking_off(self):
        single = BGEPredictor(tiny_cfg(), seed=0)
        two = BGEPredictor(tiny_cfg(RankingConfig()), seed=0)
        assert "rank_head" in two.params and "rank_head" not in single.params
        for k in single.params:
            assert trees_equal(single.params[k], two.params[k]), k

    def test_regression_path_identical_with_ranking_on(self):
        single = BGEPredictor(tiny_cfg(), seed=0)
        two = BGEPredictor(tiny_cfg(RankingConfig()), seed=0)
        toks = [[1, 2, 3], [4, 5], [6]]
        np.testing.assert_array_equal(single.predict_tokens(toks),
                                      two.predict_tokens(toks))

    def test_predict_attaches_rank_scores_in_one_dispatch(self):
        two = BGEPredictor(tiny_cfg(RankingConfig()), seed=0)
        jobs = [mk_job(i, true_len=50 + i) for i in range(3)]
        before = two.num_dispatches
        preds = two.predict(jobs)
        assert two.num_dispatches == before + 1
        assert all(p.rank_score is not None and p.rank_score > 0
                   for p in preds)
        # token-scale clip: exp([-2, 8])
        assert all(math.exp(-2) <= p.rank_score <= math.exp(8)
                   for p in preds)

    def test_single_head_predictions_carry_no_rank_score(self):
        single = BGEPredictor(tiny_cfg(), seed=0)
        [p] = single.predict([mk_job(0)])
        assert p.rank_score is None
        with pytest.raises(ValueError, match=r"ranking"):
            single.predict_tokens_ranked([[1, 2]])

    def test_two_head_fit_improves_rank_tau_smoke(self):
        # joint fit must run end to end and report both heads' metrics
        from repro.data import make_predictor_dataset

        two = BGEPredictor(tiny_cfg(RankingConfig()), seed=0)
        tr, _, te = make_predictor_dataset(40, seed=0, max_len=32,
                                           max_steps=2)
        metrics = two.fit(tr, num_steps=4, batch_size=8)
        assert all("rank_loss" in m for m in metrics.values())
        out = two.evaluate_rank(te)
        assert -1.0 <= out["kendall_tau"] <= 1.0


# --------------------------------------------------------------------------- #
# rank_by: ordering vs accounting
# --------------------------------------------------------------------------- #


class TestRankBy:
    def _policy(self, pred, rank_by, **kw):
        return make_policy(
            SchedulerConfig(policy="isrtf", rank_by=rank_by, **kw), pred)

    def test_ordering_follows_rank_head_accounting_follows_mean(self):
        # rank head orders OPPOSITE to the means: the pool order must flip
        # while expected_remaining stays on the mean
        means = {0: 10.0, 1: 20.0, 2: 30.0}
        ranks = {0: 3.0, 1: 2.0, 2: 1.0}
        jobs = [mk_job(i) for i in range(3)]
        pol = self._policy(FakeRankPredictor(means, ranks), "rank_score")
        raw = score_jobs(pol, jobs, now=0.0)
        assert raw == [3.0, 2.0, 1.0]
        assert [j.priority for j in jobs] == [3.0, 2.0, 1.0]
        assert [j.expected_remaining for j in jobs] == [10.0, 20.0, 30.0]

    def test_magnitude_default_ignores_rank_scores(self):
        means = {0: 10.0, 1: 20.0}
        ranks = {0: 99.0, 1: 1.0}
        jobs = [mk_job(i) for i in range(2)]
        pol = self._policy(FakeRankPredictor(means, ranks), "magnitude")
        raw = score_jobs(pol, jobs, now=0.0)
        assert raw == [10.0, 20.0]
        assert [j.expected_remaining for j in jobs] == [10.0, 20.0]

    def test_unknown_rank_by_lists_choices(self):
        with pytest.raises(ValueError, match=r"magnitude.*rank_score"):
            make_policy(SchedulerConfig(policy="isrtf", rank_by="nope"),
                        OraclePredictor())

    def test_rank_score_conflicts_with_risk_quantile(self):
        with pytest.raises(ValueError, match=r"mutually exclusive"):
            make_policy(SchedulerConfig(policy="isrtf", rank_by="rank_score",
                                        risk_quantile=0.9),
                        OraclePredictor())

    def test_rank_score_without_ranked_predictor_is_loud(self):
        pol = self._policy(OraclePredictor(), "rank_score")
        with pytest.raises(ValueError, match=r"two-head ranked"):
            score_jobs(pol, [mk_job(0)], now=0.0)

    def test_scale_sim_rejects_rank_by(self):
        from repro.simulate.scale import ScaleSimConfig

        with pytest.raises(ValueError, match=r"rank_by"):
            ScaleSimConfig(model="vic", rank_by="nope").validate()
        with pytest.raises(ValueError, match=r"run_experiment"):
            ScaleSimConfig(model="vic", rank_by="rank_score").validate()

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.floats(1.0, 1e4), st.floats(0.2, 2e3)),
                    min_size=1, max_size=12),
           st.sampled_from(RANK_BY))
    def test_rank_scores_never_leak_into_work_accounting(self, pool, rank_by):
        # PROPERTY: whatever orders the pool, expected_remaining (the value
        # the cluster layer's predicted-work accounting consumes) is the
        # regression mean, bit-exactly, for every job
        means = {i: m for i, (m, _) in enumerate(pool)}
        ranks = {i: r for i, (_, r) in enumerate(pool)}
        jobs = [mk_job(i) for i in range(len(pool))]
        pol = self._policy(FakeRankPredictor(means, ranks), rank_by)
        raw = score_jobs(pol, jobs, now=0.0)
        for i, j in enumerate(jobs):
            assert j.expected_remaining == means[i]
            assert j.pred_trace[-1] == (0, means[i])
            assert raw[i] == (ranks[i] if rank_by == "rank_score"
                              else means[i])


# --------------------------------------------------------------------------- #
# Calibration wrappers pass rank_score through
# --------------------------------------------------------------------------- #


class TestWrapperPassthrough:
    def _warm(self, wrapped, n=40):
        for i in range(1000, 1000 + n):
            j = mk_job(i, true_len=60)
            wrapped.predict([j])
            j.generated = [7] * 60
            j.state = JobState.FINISHED
            wrapped.observe(j, 0.0)

    def test_ema_preserves_rank_score(self):
        base = FakeRankPredictor({i: 30.0 for i in range(2000)},
                                 {i: 7.5 for i in range(2000)})
        w = EMADebiasedPredictor(base, CalibrationConfig(
            debias=True, min_samples=4, by_step=False))
        self._warm(w)
        [p] = w.predict([mk_job(0)])
        assert p.rank_score == 7.5
        assert p.mean != 30.0  # the point estimate WAS debiased

    def test_conformal_preserves_rank_score(self):
        base = FakeRankPredictor({i: 30.0 for i in range(2000)},
                                 {i: 7.5 for i in range(2000)})
        w = ConformalPredictor(base, CalibrationConfig(
            conformal=True, min_samples=4, by_step=False))
        self._warm(w)
        [p] = w.predict([mk_job(0)])
        assert p.rank_score == 7.5
        assert p.quantile(0.9) > p.mean  # the ladder IS active


# --------------------------------------------------------------------------- #
# RankedPredictor: registry, censoring, determinism, online updates
# --------------------------------------------------------------------------- #


def two_head(seed=0):
    return BGEPredictor(tiny_cfg(RankingConfig()), seed=seed)


class TestRankedPredictor:
    def test_registry_requires_two_head_bge(self):
        with pytest.raises(ValueError, match=r"two-head"):
            make_predictor("ranked")
        with pytest.raises(ValueError, match=r"two-head"):
            RankedPredictor(BGEPredictor(tiny_cfg(), seed=0))
        rp = make_predictor("ranked", bge=two_head())
        assert isinstance(rp, RankedPredictor)
        # idempotent: an already-wrapped predictor passes through
        assert make_predictor("ranked", bge=rp) is rp

    def test_unknown_registry_names_list_ranked(self):
        with pytest.raises(ValueError, match=r"ranked"):
            make_predictor("bogus")

    def test_predictions_carry_rank_scores(self):
        rp = RankedPredictor(two_head())
        preds = rp.predict([mk_job(0), mk_job(1)])
        assert all(p.rank_score is not None for p in preds)

    @pytest.mark.parametrize("state", [JobState.CANCELLED, JobState.EXPIRED])
    def test_censoring_never_forms_pairs(self, state):
        rp = RankedPredictor(two_head(), pairs_per_update=1, update_every=1)
        for i in range(6):
            j = mk_job(i, true_len=40)
            rp.predict([j])
            j.generated = [7] * (10 + i)
            j.state = state
            rp.observe(j, 0.0)
        assert rp.n_observed == 0
        assert rp.pair_log == []
        assert rp.n_updates == 0
        assert len(rp._pending) == 0
        assert len(rp._records) == 0

    def test_finished_jobs_resolve_and_censored_mixture_excluded(self):
        rp = RankedPredictor(two_head(), pairs_per_update=1, update_every=100)
        cancelled_ids = set()
        for i in range(8):
            j = mk_job(i, true_len=30 + 5 * i)
            rp.predict([j])
            if i % 2:
                j.state = JobState.CANCELLED
                cancelled_ids.add(i)
            else:
                j.generated = [7] * j.true_output_len
                j.state = JobState.FINISHED
            rp.observe(j, 0.0)
        assert rp.n_observed == 4
        assert all(rec[0] not in cancelled_ids for rec in rp._records)

    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.integers(5, 60), min_size=10, max_size=16),
           st.integers(0, 100))
    def test_pair_harvest_deterministic_under_fixed_seed(self, lens, seed):
        # PROPERTY: the harvested pair sequence and the updated params are
        # a pure function of (observation order, seed) — two identically
        # seeded instances fed the same jobs agree bit-exactly
        def run_one():
            rp = RankedPredictor(two_head(seed=1), seed=seed, window=32,
                                 pairs_per_update=2, update_every=4)
            for i, L in enumerate(lens):
                j = mk_job(i, true_len=L)
                rp.predict([j])
                j.generated = [7] * L
                j.state = JobState.FINISHED
                rp.observe(j, 0.0)
            return rp

        a, b = run_one(), run_one()
        assert a.pair_log == b.pair_log
        assert a.n_updates == b.n_updates and a.n_pairs == b.n_pairs
        assert trees_equal(a.base.params, b.base.params)
        if len(lens) >= 2 * 2:
            assert a.n_updates > 0  # the property actually exercised SGD

    def test_online_updates_touch_heads_not_encoder(self):
        base = two_head()
        rp = RankedPredictor(base, pairs_per_update=2, update_every=4)
        enc_before = jax.tree_util.tree_map(np.asarray,
                                            base.params["encoder"])
        head_before = jax.tree_util.tree_map(np.asarray, base.params["head"])
        for i in range(8):
            j = mk_job(i, true_len=20 + 7 * i)
            rp.predict([j])
            j.generated = [7] * j.true_output_len
            j.state = JobState.FINISHED
            rp.observe(j, 0.0)
        assert rp.n_updates >= 1
        assert trees_equal(enc_before, base.params["encoder"])
        assert not trees_equal(head_before, base.params["head"])

    def test_params_reassigned_not_mutated(self):
        # benchmark isolation contract: a snapshot of base.params taken
        # before online updates is never mutated in place
        base = two_head()
        rp = RankedPredictor(base, pairs_per_update=2, update_every=4)
        snap = base.params
        snap_head = jax.tree_util.tree_map(np.asarray, snap["head"])
        for i in range(8):
            j = mk_job(i, true_len=20 + 7 * i)
            rp.predict([j])
            j.generated = [7] * j.true_output_len
            j.state = JobState.FINISHED
            rp.observe(j, 0.0)
        assert rp.n_updates >= 1
        assert base.params is not snap
        assert trees_equal(snap_head, snap["head"])


# --------------------------------------------------------------------------- #
# End to end: rank-ordered ISRTF drains cleanly
# --------------------------------------------------------------------------- #


class TestEndToEnd:
    def test_rank_ordered_isrtf_drains(self):
        from repro.simulate import ExperimentConfig, run_experiment

        m = run_experiment(
            ExperimentConfig(model="vic", policy="isrtf",
                             predictor="ranked", rank_by="rank_score",
                             n_requests=12, batch_size=2, seed=0),
            bge=two_head())
        assert m["n_unfinished"] == 0 and m["n_finished"] == 12

    def test_rank_ordered_isrtf_composes_with_conformal(self):
        from repro.simulate import ExperimentConfig, run_experiment

        m = run_experiment(
            ExperimentConfig(model="vic", policy="isrtf",
                             predictor="ranked", rank_by="rank_score",
                             calibrate="conformal",
                             n_requests=10, batch_size=2, seed=1),
            bge=two_head())
        assert m["n_unfinished"] == 0

    def test_runner_rejects_rank_score_on_magnitude_predictor(self):
        from repro.simulate import ExperimentConfig, run_experiment

        with pytest.raises(ValueError, match=r"rank_score"):
            run_experiment(
                ExperimentConfig(model="vic", policy="isrtf",
                                 predictor="oracle", rank_by="rank_score",
                                 n_requests=4, batch_size=2, seed=0))
