"""Yi-6B [arXiv:2403.04652] — llama-architecture dense decoder with GQA.

32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="yi-6b",
        family="dense",
        source="arXiv:2403.04652",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        attention_type="full",
        long_context_mode="sliding_window",
        max_position_embeddings=4096,
    )
)
