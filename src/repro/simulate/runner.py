"""Experiment runner: workload -> frontend -> metrics (paper §6 harness)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    ElisServer,
    FrontendConfig,
    Job,
    PreemptionConfig,
    SchedulerConfig,
    summarize,
)
from repro.core import predictor as predictor_mod
from repro.core import api
from repro.core.metrics import (
    StreamingSummary,
    fairness_ratio,
    summarize_by_tenant,
)
from repro.data.arrivals import GammaArrivals
from repro.data.workload import (
    Request,
    WorkloadGenerator,
    build_scale_workload,
    bursty_arrival_times,
    scale_workload_requests,
)
from repro.simulate.executor import SimExecutor
from repro.simulate.profiles import PROFILES, ModelProfile, avg_request_rate

#: arrival processes ``ExperimentConfig.arrivals`` dispatches on
ARRIVAL_PROCESSES = ("bursty", "gamma")


def requests_to_jobs(requests: List[Request]) -> List[Job]:
    return [
        Job(
            job_id=r.request_id,
            prompt=r.prompt,
            prompt_tokens=r.prompt_tokens,
            arrival_time=r.arrival_time,
            true_output_len=r.true_output_len,
            output_tokens=r.output_tokens,
        )
        for r in requests
    ]


@dataclass
class ExperimentConfig:
    model: str = "lam13"
    policy: str = "isrtf"
    n_requests: int = 200         # paper: 200 prompts per experiment
    n_nodes: int = 1
    batch_size: int = 4           # paper Table 5: batch size 4
    rps_multiple: float = 1.0     # multiple of AVG.RequestRate
    window: int = 50
    predictor: str = "noisy_oracle"  # oracle | noisy_oracle | bge
    seed: int = 0
    aging_rate: float = 0.0
    preemption: PreemptionConfig = field(default_factory=PreemptionConfig)
    #: override arrival rate directly (req/s); None = rps_multiple formula
    rate_override: Optional[float] = None
    #: hardware speed multiplier (Fig 7 uses H100s: see profiles.H100_SPEEDUP)
    hw_speedup: float = 1.0
    #: full predictor re-score every N scheduling windows (ALISE-style
    #: staleness; 1 = the paper's every-window Algorithm 1)
    repredict_every: int = 1
    #: cluster placement policy: least_jobs | least_predicted_work | least_eta
    placement: str = "least_jobs"
    #: cross-node work-stealing of queued jobs at node_free events
    rebalance: bool = False
    #: predicted-work imbalance (tokens) that triggers stealing
    rebalance_threshold: float = 200.0
    #: heterogeneous cluster: node id -> profile name (PROFILES key); nodes
    #: absent from the map run ``model``'s profile.  hw_speedup applies to
    #: every node.
    node_profiles: Optional[Dict[int, str]] = None
    #: arrival process: "gamma" (FabriX-calibrated) | "bursty" (flash
    #: crowds, repro.data.workload.bursty_arrival_times)
    arrivals: str = "gamma"
    #: run a registered traffic scenario (repro.data.workload.SCENARIOS:
    #: diurnal | multi_tenant_slo | flash_crowd) instead of the default
    #: LMSYS-style workload + ``arrivals`` process; scenario workloads carry
    #: their own arrivals, tenants, priority classes and SLO targets, and
    #: the summary gains per-tenant metrics + a JCT fairness ratio
    scenario: Optional[str] = None
    #: requests per flash crowd when ``arrivals="bursty"``
    burst_size: int = 8
    #: serving-time calibration over the base predictor:
    #: none | ema | conformal | ema+conformal
    #: (repro.core.predictor.wrap_calibration)
    calibrate: str = "none"
    #: risk-aware ISRTF: rank on this calibrated upper quantile instead of
    #: the point estimate (None = the paper's mean ranking)
    risk_quantile: Optional[float] = None
    #: pool-ordering source for re-predicting policies: "magnitude" (the
    #: calibrated mean / risk quantile) | "rank_score" (the learning-to-rank
    #: head — needs predictor="ranked" with a two-head bge).  Load
    #: accounting stays on the mean either way (SchedulerConfig.rank_by)
    rank_by: str = "magnitude"
    #: synthetic multiplicative mis-calibration injected into the noisy
    #: oracle (< 1 = systematic underestimates); 1.0 = unbiased
    predictor_bias: float = 1.0
    #: feed ground-truth remaining to predictor.observe every window (the
    #: simulator replays realised lengths, so truth is available mid-flight)
    observe_in_flight: bool = True
    #: chunked prefill: split prompt ingestion into chunks of this many
    #: tokens, at most one chunk per scheduling window, interleaved with
    #: decode (None = one-shot prefill)
    prefill_chunk: Optional[int] = None
    #: host<->device KV transfer bandwidth/latency the swap preemption
    #: tier is priced with (PreemptionConfig.policy = swap | auto)
    swap_bandwidth_bytes_s: float = 16e9
    swap_latency_s: float = 0.0005


def make_predictor(kind: str, seed: int = 0, bge=None, *,
                   calibration=None, bias: float = 1.0):
    """Back-compat wrapper over :func:`repro.core.predictor.make_predictor`
    (the registry), keeping the old positional (kind, seed, bge) call."""
    cal = None if calibration in (None, "none") else calibration
    return predictor_mod.make_predictor(kind, seed=seed, bge=bge,
                                        calibration=cal, bias=bias)


def run_experiment(cfg: ExperimentConfig, *, bge=None,
                   requests: Optional[List[Request]] = None,
                   stream_metrics: bool = False) -> Dict[str, float]:
    try:
        profile = PROFILES[cfg.model]
    except KeyError:
        raise ValueError(f"unknown model {cfg.model!r} "
                         f"(have {sorted(PROFILES)})") from None
    if cfg.hw_speedup != 1.0:
        profile = profile.scaled(cfg.hw_speedup)
    rng = np.random.RandomState(cfg.seed)

    rate = cfg.rate_override
    if rate is None:
        rate = avg_request_rate(profile, cfg.batch_size) * cfg.rps_multiple
        rate *= cfg.n_nodes
    scale_w = None
    if cfg.scenario is not None:
        if requests is not None:
            raise ValueError(
                "ExperimentConfig.scenario and explicit requests are "
                "mutually exclusive — scenarios build their own workload")
        # fails loudly on unknown names, listing the registry
        scale_w = build_scale_workload(cfg.scenario, cfg.n_requests, rate,
                                       rng)
        requests = scale_workload_requests(scale_w)
    else:
        if requests is None:
            gen = WorkloadGenerator(seed=cfg.seed)
            requests = gen.sample_requests(cfg.n_requests)
        if cfg.arrivals == "bursty":
            times = bursty_arrival_times(len(requests), rate, rng,
                                         burst_size=cfg.burst_size)
        elif cfg.arrivals == "gamma":
            times = GammaArrivals().rate_scaled(rate).sample_arrival_times(
                len(requests), rng)
        else:
            raise ValueError(f"unknown arrivals {cfg.arrivals!r} "
                             f"(have {list(ARRIVAL_PROCESSES)})")
        for r, t in zip(requests, times):
            r.arrival_time = float(t)

    node_profiles = None
    if cfg.node_profiles:
        node_profiles = {
            int(n): (PROFILES[name].scaled(cfg.hw_speedup)
                     if cfg.hw_speedup != 1.0 else PROFILES[name])
            for n, name in cfg.node_profiles.items()
        }
    executor = SimExecutor(profile, node_profiles=node_profiles,
                           swap_bandwidth_bytes_s=cfg.swap_bandwidth_bytes_s,
                           swap_latency_s=cfg.swap_latency_s)

    predictor = make_predictor(cfg.predictor, seed=cfg.seed + 1, bge=bge,
                               calibration=cfg.calibrate,
                               bias=cfg.predictor_bias)
    fe_cfg = FrontendConfig(
        n_nodes=cfg.n_nodes,
        scheduler=SchedulerConfig(
            policy=cfg.policy, window=cfg.window, batch_size=cfg.batch_size,
            aging_rate=cfg.aging_rate, repredict_every=cfg.repredict_every,
            risk_quantile=cfg.risk_quantile,
            prefill_chunk=cfg.prefill_chunk,
            rank_by=cfg.rank_by,
        ),
        preemption=cfg.preemption,
        placement=cfg.placement,
        node_token_cost=executor.node_token_cost(cfg.n_nodes),
        rebalance=cfg.rebalance,
        rebalance_threshold=cfg.rebalance_threshold,
        observe_in_flight=cfg.observe_in_flight,
    )
    server = ElisServer(fe_cfg, predictor, executor)
    for r in requests:
        server.submit(api.Request.from_workload(r))
    slo_targets = dict(scale_w.slo_targets) if scale_w is not None else {}
    if stream_metrics:
        # constant-memory aggregation: responses are consumed (and their
        # job records released) as they stream out of the server
        g = StreamingSummary()
        per_tenant: Dict[str, StreamingSummary] = {}
        n_unfinished = 0
        for resp in server.drain_stream():
            if not resp.ok:
                n_unfinished += 1
                continue
            g.add_response(resp)
            s = per_tenant.get(resp.tenant)
            if s is None:
                s = per_tenant[resp.tenant] = StreamingSummary(
                    slo_target=slo_targets.get(resp.tenant))
            s.add_response(resp)
        server.frontend.state.assert_drained()
        m = g.summarize()
        m["n_finished"] = g.n
        m["n_unfinished"] = n_unfinished
        if cfg.scenario is not None:
            m["tenants"] = {t: s.summarize()
                            for t, s in sorted(per_tenant.items())}
            m["fairness_jct"] = fairness_ratio(
                {t: s.sketch.mean for t, s in per_tenant.items()})
    else:
        responses = server.drain()
        # cluster-accounting invariant: every admitted job is terminal, so
        # the load balancer's live-count and predicted-work totals are back
        # to zero
        server.frontend.state.assert_drained()
        done = [r for r in responses if r.ok]
        m = summarize(done)
        m["n_finished"] = len(done)
        m["n_unfinished"] = len(responses) - len(done)
        if cfg.scenario is not None:
            m["tenants"] = summarize_by_tenant(done, slo_targets)
            m["fairness_jct"] = fairness_ratio(
                {t: s["jct_mean"] for t, s in m["tenants"].items()})
    m.update(executor.counters())
    m["migrations"] = server.frontend.migrations
    return m


def compare_policies(base_cfg: ExperimentConfig, policies=("fcfs", "isrtf", "sjf"),
                     *, bge=None, n_trials: int = 3) -> Dict[str, Dict]:
    """Paper §6.2: same sampled prompts, shuffled per trial, 3 repeats."""
    import dataclasses

    out: Dict[str, Dict] = {}
    for pol in policies:
        trials = []
        for t in range(n_trials):
            cfg = dataclasses.replace(
                base_cfg,
                policy=pol,
                seed=base_cfg.seed + 1000 * t,
                predictor="oracle" if pol == "sjf" else base_cfg.predictor,
            )
            trials.append(run_experiment(cfg, bge=bge))
        agg = {
            k: float(np.mean([tr[k] for tr in trials]))
            for k in trials[0]
        }
        agg["jct_mean_min"] = float(np.min([tr["jct_mean"] for tr in trials]))
        agg["jct_mean_max"] = float(np.max([tr["jct_mean"] for tr in trials]))
        out[pol] = agg
    return out
