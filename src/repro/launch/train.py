"""Training launcher for any assigned architecture.

On CPU this trains the reduced variant for real; with ``--dry-run`` it
lowers+compiles the FULL config's train step on the production mesh instead
(delegating to repro.launch.dryrun) — the same entrypoint a TPU job would use.

    python -m repro.launch.train --arch yi-6b --steps 100
    python -m repro.launch.train --arch yi-6b --dry-run --mesh multi
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data import WorkloadGenerator
from repro.models import init_params, loss_fn
from repro.training import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def batches(cfg, batch_size, seq_len, seed=0):
    gen = WorkloadGenerator(seed=seed)
    buf = []
    while True:
        while len(buf) < batch_size * (seq_len + 1):
            r = gen.sample_request()
            buf.extend(t % cfg.vocab_size for t in r.prompt_tokens)
            buf.extend(t % cfg.vocab_size for t in r.output_tokens)
        chunk = np.asarray(buf[: batch_size * (seq_len + 1)], np.int32)
        buf = buf[batch_size * (seq_len + 1):]
        chunk = chunk.reshape(batch_size, seq_len + 1)
        batch = {"tokens": jnp.asarray(chunk[:, :-1]),
                 "labels": jnp.asarray(chunk[:, 1:])}
        if cfg.family == "vlm":
            batch["embeds"] = jnp.zeros((batch_size, cfg.frontend_tokens,
                                         cfg.d_model))
            batch["labels"] = jnp.pad(batch["labels"],
                                      ((0, 0), (cfg.frontend_tokens, 0)),
                                      constant_values=-1)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((batch_size, cfg.encoder.n_frames,
                                         cfg.d_model))
        yield batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(list_archs()))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the FULL config on the production mesh")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()

    if args.dry_run:
        # delegate: the dry-run module must own the XLA device-count env var
        os.execvp(sys.executable, [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", "train_4k", "--mesh", args.mesh,
        ])

    cfg = get_config(args.arch).reduced()
    print(f"[train] {cfg.arch_id}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch {args.batch} x seq {args.seq}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt_state = adamw_init(params)
    start = 0
    if args.resume and args.ckpt:
        step0 = latest_step(args.ckpt)
        if step0 is not None:
            params, meta = restore_checkpoint(args.ckpt, step0, params)
            start = step0
            print(f"[train] resumed from step {step0}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        (l, aux), grads = jax.value_and_grad(
            lambda p, b: loss_fn(p, cfg, b, remat=True), has_aux=True
        )(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        return params, opt_state, l, metrics["grad_norm"]

    it = batches(cfg, args.batch, args.seq)
    t0 = time.time()
    for i in range(start, start + args.steps):
        params, opt_state, loss, gnorm = step_fn(params, opt_state, next(it))
        if i % args.log_every == 0 or i == start + args.steps - 1:
            print(f"step {i:5d}  loss {float(loss):7.4f}  "
                  f"gnorm {float(gnorm):8.3f}  {time.time()-t0:5.0f}s")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, start + args.steps, params,
                               metadata={"loss": float(loss),
                                         "arch": args.arch})
        print(f"[train] checkpoint: {path}")


if __name__ == "__main__":
    main()
