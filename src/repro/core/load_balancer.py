"""Greedy min-load balancer over backend workers (paper §4.1 line 3).

Consults the global state G — the number of live jobs per backend — and
assigns each new job to the worker executing the fewest (StatefulSet pod
identity maps to the integer node id).
"""
from __future__ import annotations

from typing import Dict, List


class GlobalState:
    """The frontend's shared-memory view of the cluster."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.active_jobs: Dict[int, int] = {n: 0 for n in range(n_nodes)}
        self.busy_until: Dict[int, float] = {n: 0.0 for n in range(n_nodes)}

    def add_job(self, node: int) -> None:
        self.active_jobs[node] += 1

    def finish_job(self, node: int) -> None:
        self.active_jobs[node] -= 1
        assert self.active_jobs[node] >= 0


class LoadBalancer:
    def __init__(self, state: GlobalState):
        self.state = state

    def get_min_load(self) -> int:
        return min(self.state.active_jobs, key=lambda n: (self.state.active_jobs[n], n))

    def assign(self, job) -> int:
        node = self.get_min_load()
        job.node = node
        self.state.add_job(node)
        return node
