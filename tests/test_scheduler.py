"""Scheduler policy unit + property tests (hypothesis)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Job,
    NoisyOraclePredictor,
    OraclePredictor,
    PreemptionConfig,
    SchedulerConfig,
    make_policy,
    select_preemptions,
)
from repro.core.scheduler import batch_effective, score_pool

from _helpers import CountingOracle


def mk_job(i, arrival=0.0, true_len=100, generated=0):
    j = Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1, 2, 3],
            arrival_time=arrival, true_output_len=true_len)
    j.generated = [7] * generated
    return j


def test_fcfs_orders_by_arrival():
    pol = make_policy(SchedulerConfig(policy="fcfs"), None)
    jobs = [mk_job(0, arrival=5.0), mk_job(1, arrival=1.0)]
    pris = batch_effective(pol, jobs, now=10.0)
    assert pris[1] < pris[0]


def test_isrtf_prefers_short_remaining():
    pol = make_policy(SchedulerConfig(policy="isrtf"), OraclePredictor())
    jobs = [mk_job(0, true_len=500), mk_job(1, true_len=20)]
    pris = batch_effective(pol, jobs, now=0.0)
    assert pris[1] < pris[0]


def test_isrtf_priority_updates_with_progress():
    pol = make_policy(SchedulerConfig(policy="isrtf"), OraclePredictor())
    j = mk_job(0, true_len=500)
    p0 = batch_effective(pol, [j], now=0.0)[0]
    j.generated = [7] * 450
    p1 = batch_effective(pol, [j], now=1.0)[0]
    assert p1 < p0


def test_sjf_keeps_first_estimate():
    pol = make_policy(SchedulerConfig(policy="sjf"), OraclePredictor())
    j = mk_job(0, true_len=300)
    p0 = batch_effective(pol, [j], now=0.0)[0]
    j.true_output_len = 999  # oracle would now say 999 - but SJF is one-shot
    j.generated = [7] * 50
    p1 = batch_effective(pol, [j], now=1.0)[0]
    assert p1 == pytest.approx(p0 - 50)


def test_aging_prevents_starvation():
    cfg = SchedulerConfig(policy="isrtf", aging_rate=10.0)
    pol = make_policy(cfg, OraclePredictor())
    old = mk_job(0, true_len=1000)
    old.record_enqueue(0.0)
    young = mk_job(1, true_len=10)
    young.record_enqueue(99.9)
    pris = batch_effective(pol, [old, young], now=100.0)
    assert pris[0] < pris[1]  # 1000 - 10*100 < 10


def test_mlfq_demotes_by_service():
    pol = make_policy(SchedulerConfig(policy="mlfq"), None)
    fresh = mk_job(0, arrival=50.0, generated=0)
    served = mk_job(1, arrival=0.0, generated=300)
    pris = batch_effective(pol, [fresh, served], now=60.0)
    assert pris[0] < pris[1]


def test_requires_predictor():
    with pytest.raises(ValueError):
        make_policy(SchedulerConfig(policy="isrtf"), None)
    with pytest.raises(ValueError):
        make_policy(SchedulerConfig(policy="nope"), OraclePredictor())


# --------------------------------------------------------------------------- #
# Preemption policy properties
# --------------------------------------------------------------------------- #


@given(
    run=st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
    wait=st.lists(st.floats(1, 1e4), min_size=1, max_size=8),
    margin=st.floats(0, 100),
    frac=st.floats(0, 1),
)
@settings(max_examples=200, deadline=None)
def test_preemption_properties(run, wait, margin, frac):
    running = [(p, mk_job(100 + i)) for i, p in enumerate(run)]
    waiting = [(p, mk_job(200 + i)) for i, p in enumerate(wait)]
    cfg = PreemptionConfig(enabled=True, margin=margin, max_fraction=frac)
    swaps = select_preemptions(running, waiting, cfg)
    # budget respected (ceiling: an enabled policy with frac > 0 may always
    # displace at least one victim, even for tiny running batches)
    assert len(swaps) <= math.ceil(len(running) * frac)
    if frac == 0:
        assert swaps == []
    # each swap strictly beats the victim by the margin
    run_pri = {j.job_id: p for p, j in running}
    wait_pri = {j.job_id: p for p, j in waiting}
    for victim, repl in swaps:
        assert wait_pri[repl.job_id] + margin < run_pri[victim.job_id]
    # no duplicates
    assert len({v.job_id for v, _ in swaps}) == len(swaps)
    assert len({r.job_id for _, r in swaps}) == len(swaps)


def test_preemption_disabled():
    running = [(100.0, mk_job(0))]
    waiting = [(1.0, mk_job(1))]
    assert select_preemptions(running, waiting,
                              PreemptionConfig(enabled=False)) == []


def test_preemption_budget_ceil_small_batches():
    """Regression: int() floored the budget to 0 for <= 3 running jobs at
    the default max_fraction=0.25, silently disabling preemption whenever
    the default batch_size=4 had a free slot."""
    cfg = PreemptionConfig(enabled=True, margin=50.0, max_fraction=0.25)
    for n_running in (1, 2, 3):
        running = [(1000.0 + i, mk_job(100 + i)) for i in range(n_running)]
        waiting = [(1.0, mk_job(200))]
        swaps = select_preemptions(running, waiting, cfg)
        assert len(swaps) == 1, f"no preemption with {n_running} running"
    # a zero fraction still means "never preempt"
    assert select_preemptions([(1000.0, mk_job(0))], [(1.0, mk_job(1))],
                              PreemptionConfig(enabled=True, margin=0.0,
                                               max_fraction=0.0)) == []


# --------------------------------------------------------------------------- #
# Fused scoring pass + re-prediction stride
# --------------------------------------------------------------------------- #


def test_score_pool_single_dispatch_and_split():
    pred = CountingOracle()
    pol = make_policy(SchedulerConfig(policy="isrtf"), pred)
    running = [mk_job(0, true_len=300, generated=100), mk_job(1, true_len=80)]
    waiting = [mk_job(2, true_len=40), mk_job(3, true_len=500)]
    run_eff, wait_eff = score_pool(pol, running, waiting, now=0.0)
    assert pred.dispatches == 1
    assert run_eff == [200.0, 80.0]
    assert wait_eff == [40.0, 500.0]
    # scores recorded on the jobs (history + staleness watermark)
    for j in running + waiting:
        assert j.predictions == [j.priority]
        assert j.tokens_at_last_score == j.tokens_generated


def test_score_pool_fused_matches_two_pass_reference():
    """At repredict_every=1 the fused pass must reproduce the old two-pass
    (running then waiting) effective priorities exactly."""
    cfg = SchedulerConfig(policy="isrtf", aging_rate=2.0)
    mk = lambda: ([mk_job(0, true_len=300, generated=50),
                   mk_job(1, true_len=90)],
                  [mk_job(2, true_len=40), mk_job(3, true_len=700)])

    def prep(running, waiting):
        for j, klass in zip(running + waiting, (0, 1, 0, 2)):
            j.priority_class = klass
            j.record_enqueue(float(j.job_id))
        return running, waiting

    r1, w1 = prep(*mk())
    pol = make_policy(cfg, OraclePredictor())
    ref_run = batch_effective(pol, r1, now=10.0)
    ref_wait = batch_effective(pol, w1, now=10.0)

    r2, w2 = prep(*mk())
    got_run, got_wait = score_pool(pol, r2, w2, now=10.0)
    assert got_run == ref_run
    assert got_wait == ref_wait


def test_stride_reuses_decayed_prediction():
    pred = CountingOracle()
    pol = make_policy(SchedulerConfig(policy="isrtf", repredict_every=4), pred)
    j = mk_job(0, true_len=500)
    [eff], _ = score_pool(pol, [j], [], now=0.0, full=True)
    assert eff == 500.0 and pred.dispatches == 1
    # stale window: prediction reused minus progress, no predictor call
    j.generated = [7] * 50
    [eff], _ = score_pool(pol, [j], [], now=1.0, full=False)
    assert eff == 450.0 and pred.dispatches == 1
    # prediction history only grows on full re-scores
    assert j.predictions == [500.0]
    # a never-scored arrival is still scored fresh on a stale window
    new = mk_job(1, true_len=70)
    _, [new_eff] = score_pool(pol, [j], [new], now=2.0, full=False)
    assert new_eff == 70.0 and pred.dispatches == 2
    assert new.predictions == [70.0]


def test_stride_stale_priority_never_negative():
    pred = CountingOracle()
    pol = make_policy(SchedulerConfig(policy="isrtf", repredict_every=8), pred)
    j = mk_job(0, true_len=10)
    score_pool(pol, [j], [], now=0.0, full=True)
    j.generated = [7] * 200          # progressed far past the estimate
    [eff], _ = score_pool(pol, [j], [], now=1.0, full=False)
    assert eff == 0.0


def test_cached_raw_priority_consistent_with_stale_scoring():
    """The preemption swap path re-bands a victim with cached_raw_priority;
    it must equal what the same window's scoring pass produced (decayed on
    stale windows, fresh right after a score) — never the undecayed cache."""
    from repro.core.scheduler import cached_raw_priority

    pred = CountingOracle()
    pol = make_policy(SchedulerConfig(policy="isrtf", repredict_every=4), pred)
    j = mk_job(0, true_len=500)
    score_pool(pol, [j], [], now=0.0, full=True)
    assert cached_raw_priority(j) == 500.0      # fresh: zero decay
    j.generated = [7] * 50
    [eff], _ = score_pool(pol, [j], [], now=1.0, full=False)
    assert cached_raw_priority(j) == 450.0 == eff
    # a job scored fresh on the stale window also agrees
    k = mk_job(1, true_len=80)
    _, [k_eff] = score_pool(pol, [j], [k], now=1.0, full=False)
    assert cached_raw_priority(k) == 80.0 == k_eff


def test_stride_does_not_decay_fcfs_priorities():
    """Stale reuse only applies to re-predicting policies — FCFS priorities
    are arrival times and must never be decayed by token progress."""
    pol = make_policy(SchedulerConfig(policy="fcfs", repredict_every=4), None)
    j = mk_job(0, arrival=123.0)
    score_pool(pol, [j], [], now=0.0, full=True)
    j.generated = [7] * 100
    [eff], _ = score_pool(pol, [j], [], now=1.0, full=False)
    assert eff == 123.0


@given(st.lists(st.integers(1, 1000), min_size=2, max_size=30))
@settings(max_examples=100, deadline=None)
def test_noisy_oracle_positive_and_decaying_sigma(lens):
    pred = NoisyOraclePredictor(seed=1)
    for i, l in enumerate(lens):
        j = mk_job(i, true_len=l)
        p = pred.init(j)
        assert p >= 1.0
    assert pred._sigma(5) < pred._sigma(0)
    assert pred._sigma(100) == pred.sigma_floor
