"""Scale simulation subsystem (repro.simulate.scale + scenario library).

The load-bearing property: the vectorized fast path is *trace-identical*
to the exact ``ELISFrontend`` event loop on every supported config — same
IEEE arithmetic in the same order, so per-job finish times, queueing
delays, preemption counts and finish order match bitwise, not just
statistically.  The property tests sweep policy x predictor x preemption
x placement x cluster shape over randomized workloads (priority classes,
deadlines, multi-tenant mixes) and diff every outcome array.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metrics import (
    QuantileSketch,
    StreamingSummary,
    fairness_ratio,
    summarize,
)
from repro.core.scheduler import select_fills
from repro.data.workload import (
    SCENARIOS,
    ScaleWorkload,
    build_scale_workload,
    scale_workload_requests,
)
from repro.simulate import ExperimentConfig, run_experiment
from repro.simulate.scale import (
    EXPIRED,
    FINISHED,
    ScaleSimConfig,
    ScaleSimulator,
    run_exact_reference,
)

# --------------------------------------------------------------------------- #
# Randomized workloads for the fidelity sweep
# --------------------------------------------------------------------------- #


def _random_workload(seed: int, n: int = 36, *, rate: float = 1.2,
                     with_deadlines: bool = False) -> ScaleWorkload:
    """A small adversarial workload: bursty arrivals (ties included),
    mixed lengths spanning several scheduling windows, two tenants, two
    priority bands, optional finite deadlines."""
    rng = np.random.RandomState(seed)
    arrival = np.sort(np.round(rng.uniform(0.0, n / rate, size=n), 2))
    # duplicate a few arrival times: same-instant submissions exercise the
    # event heap's seq tie-break
    if n >= 4:
        arrival[1] = arrival[0]
        arrival[n // 2] = arrival[n // 2 - 1]
    length = rng.randint(1, 130, size=n).astype(np.int64)
    tenant_id = rng.randint(0, 2, size=n).astype(np.int32)
    klass = rng.randint(0, 2, size=n).astype(np.int16)
    deadline = np.full(n, np.inf)
    if with_deadlines:
        tight = rng.rand(n) < 0.35
        deadline[tight] = arrival[tight] + rng.uniform(2.0, 60.0,
                                                       size=int(tight.sum()))
    return ScaleWorkload(
        arrival=arrival, length=length,
        prompt_len=np.full(n, 12, np.int64),
        tenant_id=tenant_id, priority_class=klass, deadline=deadline,
        tenants=("alpha", "beta"), slo_targets={"alpha": 30.0})


def _assert_trace_identical(fast, exact, ctx):
    np.testing.assert_array_equal(fast.state, exact.state, err_msg=ctx)
    np.testing.assert_array_equal(fast.finished_order, exact.finished_order,
                                  err_msg=ctx)
    np.testing.assert_array_equal(fast.n_preemptions, exact.n_preemptions,
                                  err_msg=ctx)
    np.testing.assert_array_equal(fast.n_iterations, exact.n_iterations,
                                  err_msg=ctx)
    for name in ("finish", "first_token", "queuing_delay"):
        f = getattr(fast, name)
        e = getattr(exact, name)
        assert np.array_equal(f, e, equal_nan=True), (
            f"{ctx}: {name} diverges (max delta "
            f"{np.nanmax(np.abs(f - e))})")


# --------------------------------------------------------------------------- #
# Fast path == exact frontend (the subsystem's core contract)
# --------------------------------------------------------------------------- #


class TestFastPathFidelity:
    @settings(max_examples=20, deadline=None)
    @given(policy=st.sampled_from(["fcfs", "sjf", "isrtf"]),
           predictor=st.sampled_from(["oracle", "noisy_oracle"]),
           preempt=st.booleans(),
           n_nodes=st.sampled_from([1, 2, 3]),
           placement=st.sampled_from(["least_jobs", "least_predicted_work",
                                      "least_eta"]),
           aging=st.sampled_from([0.0, 0.05]),
           repredict=st.sampled_from([1, 3]),
           coalesce=st.booleans(),
           deadlines=st.booleans(),
           seed=st.integers(0, 10_000))
    def test_trace_identical_sweep(self, policy, predictor, preempt, n_nodes,
                                   placement, aging, repredict, coalesce,
                                   deadlines, seed):
        from repro.core import PreemptionConfig

        cfg = ScaleSimConfig(
            model="vic", policy=policy, predictor=predictor,
            n_nodes=n_nodes, batch_size=3, window=50,
            aging_rate=aging, repredict_every=repredict,
            preemption=PreemptionConfig(enabled=preempt),
            placement=placement, seed=seed, coalesce=coalesce)
        w = _random_workload(seed, with_deadlines=deadlines)
        fast = ScaleSimulator(cfg).run(w)
        exact = run_exact_reference(cfg, w)
        _assert_trace_identical(fast, exact, ctx=repr(cfg))

    def test_heterogeneous_cluster(self):
        cfg = ScaleSimConfig(model="vic", policy="isrtf", n_nodes=3,
                             batch_size=2, hw_speedup=2.0,
                             node_profiles={1: "lam13"},
                             placement="least_eta", seed=7)
        w = _random_workload(7, n=48)
        fast = ScaleSimulator(cfg).run(w)
        exact = run_exact_reference(cfg, w)
        _assert_trace_identical(fast, exact, ctx="hetero")

    def test_coalescing_fires_and_stays_exact(self):
        # a sparse trickle leaves nodes with empty queues for long
        # stretches: the coalesced-window fast-forward must engage AND
        # remain bit-exact
        cfg = ScaleSimConfig(model="vic", policy="isrtf", n_nodes=1,
                             batch_size=4, seed=11, coalesce=True)
        w = _random_workload(11, n=24, rate=0.08)
        res = ScaleSimulator(cfg).run(w)
        assert res.n_coalesced > 0, "sparse workload never coalesced"
        exact = run_exact_reference(cfg, w)
        _assert_trace_identical(res, exact, ctx="coalesce")

    def test_deadlines_expire_identically(self):
        cfg = ScaleSimConfig(model="vic", policy="fcfs", n_nodes=1,
                             batch_size=2, seed=3)
        w = _random_workload(3, n=40, rate=4.0, with_deadlines=True)
        fast = ScaleSimulator(cfg).run(w)
        exact = run_exact_reference(cfg, w)
        _assert_trace_identical(fast, exact, ctx="deadlines")
        assert (fast.state == EXPIRED).any(), \
            "workload was meant to blow some deadlines"


# --------------------------------------------------------------------------- #
# Determinism
# --------------------------------------------------------------------------- #


class TestDeterminism:
    def test_repeat_runs_bit_equal(self):
        cfg = ScaleSimConfig(model="vic", policy="isrtf",
                             predictor="noisy_oracle", n_nodes=2,
                             batch_size=3, seed=5,
                             placement="least_predicted_work")

        def once():
            rng = np.random.RandomState(5)
            w = build_scale_workload("multi_tenant_slo", 300, 2.0, rng)
            return w, ScaleSimulator(cfg).run(w)

        w1, r1 = once()
        w2, r2 = once()
        np.testing.assert_array_equal(w1.arrival, w2.arrival)
        np.testing.assert_array_equal(w1.length, w2.length)
        np.testing.assert_array_equal(r1.finish, r2.finish)
        np.testing.assert_array_equal(r1.state, r2.state)
        np.testing.assert_array_equal(r1.finished_order, r2.finished_order)
        assert r1.metrics()["jct_mean"] == r2.metrics()["jct_mean"]

    def test_seed_changes_outcome(self):
        rng = np.random.RandomState(0)
        w0 = build_scale_workload("diurnal", 200, 2.0, rng)
        w1 = build_scale_workload("diurnal", 200, 2.0,
                                  np.random.RandomState(1))
        assert not np.array_equal(w0.arrival, w1.arrival)


# --------------------------------------------------------------------------- #
# Streaming metrics
# --------------------------------------------------------------------------- #


class TestStreamingMetrics:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), q=st.sampled_from([0.5, 0.9, 0.99]))
    def test_sketch_quantile_within_tolerance(self, seed, q):
        rng = np.random.RandomState(seed)
        x = rng.lognormal(1.0, 1.2, size=3000)
        sk = QuantileSketch()
        sk.add(x[:1000])
        sk.add(x[1000:])  # incremental ingestion
        v = sk.quantile(q)
        tol = 1.0 + sk.rel_error
        rank = q * (len(x) - 1)
        # v is within rel_error of a true q-quantile point of the sample:
        # at least `rank` samples sit at or below v*(1+eps), and at most
        # `rank` sit strictly below v/(1+eps)
        assert np.sum(x <= v * tol) >= rank
        assert np.sum(x < v / tol) <= rank + 1

    def test_streaming_matches_exact_summarize(self):
        m = run_experiment(
            ExperimentConfig(scenario="multi_tenant_slo", n_requests=100,
                             model="vic", predictor="oracle", seed=2),
            stream_metrics=True)
        m_exact = run_experiment(
            ExperimentConfig(scenario="multi_tenant_slo", n_requests=100,
                             model="vic", predictor="oracle", seed=2))
        # counts / sums / extremes are exact in the streaming path
        for k in ("n", "n_finished", "jct_mean", "jct_min", "jct_max",
                  "queuing_delay_mean", "makespan", "preemptions",
                  "ttft_mean"):
            assert m[k] == pytest.approx(m_exact[k], rel=1e-12), k
        # quantiles carry the sketch's documented tolerance (plus the
        # interpolation difference of np.percentile at small n)
        for k in ("jct_p50", "jct_p99"):
            assert m[k] == pytest.approx(m_exact[k], rel=0.06), k
        assert set(m["tenants"]) == set(m_exact["tenants"])
        for t, tm in m["tenants"].items():
            assert tm["n"] == m_exact["tenants"][t]["n"]
            if "slo_attainment" in m_exact["tenants"][t]:
                assert tm["slo_attainment"] == pytest.approx(
                    m_exact["tenants"][t]["slo_attainment"])

    def test_merge_equals_bulk(self):
        rng = np.random.RandomState(9)
        x = rng.lognormal(0.0, 1.0, size=500)
        whole = QuantileSketch()
        whole.add(x)
        a, b = QuantileSketch(), QuantileSketch()
        a.add(x[:123])
        b.add(x[123:])
        a.merge(b)
        np.testing.assert_array_equal(a.counts, whole.counts)
        assert a.n == whole.n and a.min == whole.min and a.max == whole.max

    def test_scale_result_metrics_surface(self):
        rng = np.random.RandomState(4)
        w = build_scale_workload("multi_tenant_slo", 400, 2.5, rng)
        res = ScaleSimulator(ScaleSimConfig(model="vic", seed=4)).run(w)
        m = res.metrics()
        assert m["n_finished"] + m["n_expired"] <= w.n
        assert m["n_finished"] == int((res.state == FINISHED).sum())
        assert set(m["tenants"]) <= set(w.tenants)
        assert m["requests_per_s"] > 0
        # per-tenant ns roll up to the global count
        assert sum(tm["n"] for tm in m["tenants"].values()) == m["n"]
        # interactive tenant carries an SLO target -> attainment reported
        assert "slo_attainment" in m["tenants"]["interactive"]

    def test_fairness_ratio(self):
        assert fairness_ratio({"a": 2.0, "b": 1.0}) == 2.0
        assert fairness_ratio({"a": 1.0}) == 0.0
        assert fairness_ratio({}) == 0.0


# --------------------------------------------------------------------------- #
# Scenario library
# --------------------------------------------------------------------------- #


class TestScenarios:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_builds_sorted_and_valid(self, name):
        rng = np.random.RandomState(0)
        w = build_scale_workload(name, 500, 2.0, rng)
        assert w.n == 500
        assert (np.diff(w.arrival) >= 0).all()
        assert w.length.min() >= 1
        assert w.tenant_id.max() < len(w.tenants)

    def test_multi_tenant_mix(self):
        rng = np.random.RandomState(0)
        w = build_scale_workload("multi_tenant_slo", 1000, 2.0, rng)
        assert set(w.tenants) == {"interactive", "agent", "batch"}
        assert set(w.slo_targets) == set(w.tenants)
        # every tenant actually contributes traffic
        assert len(np.unique(w.tenant_id)) == 3
        # priority classes separate the bands
        assert len(np.unique(w.priority_class)) > 1

    def test_requests_round_trip(self):
        rng = np.random.RandomState(1)
        w = build_scale_workload("multi_tenant_slo", 50, 2.0, rng)
        reqs = scale_workload_requests(w)
        assert len(reqs) == 50
        assert [r.arrival_time for r in reqs] == list(w.arrival)
        assert [r.true_output_len for r in reqs] == list(w.length)
        assert {r.tenant for r in reqs} <= set(w.tenants)

    def test_head_slices_consistently(self):
        rng = np.random.RandomState(2)
        w = build_scale_workload("flash_crowd", 300, 3.0, rng)
        h = w.head(40)
        assert h.n == 40
        np.testing.assert_array_equal(h.arrival, w.arrival[:40])
        assert h.tenants == w.tenants


# --------------------------------------------------------------------------- #
# Loud dispatch errors (unknown string names never fall through silently)
# --------------------------------------------------------------------------- #


class TestLoudErrors:
    def test_unknown_scenario(self):
        with pytest.raises(ValueError, match="diurnal"):
            build_scale_workload("weekday", 10, 1.0, np.random.RandomState(0))

    def test_unknown_arrivals(self):
        with pytest.raises(ValueError, match="bursty"):
            run_experiment(ExperimentConfig(n_requests=4, arrivals="poisson"))

    def test_unknown_model(self):
        with pytest.raises(ValueError, match="vic"):
            run_experiment(ExperimentConfig(n_requests=4, model="gpt5"))

    def test_unknown_scenario_via_config(self):
        with pytest.raises(ValueError, match="multi_tenant_slo"):
            run_experiment(ExperimentConfig(n_requests=4, scenario="nope"))

    def test_scenario_and_requests_exclusive(self):
        rng = np.random.RandomState(0)
        reqs = scale_workload_requests(
            build_scale_workload("diurnal", 4, 1.0, rng))
        with pytest.raises(ValueError, match="mutually exclusive"):
            run_experiment(ExperimentConfig(n_requests=4, scenario="diurnal"),
                           requests=reqs)

    @pytest.mark.parametrize("field,value,expect", [
        ("model", "nope", "unknown model"),
        ("policy", "mlfq", "unsupported policy"),
        ("predictor", "bge", "unsupported predictor"),
        ("placement", "round_robin", "unknown placement"),
        ("node_profiles", {0: "h100"}, "unknown profile"),
    ])
    def test_scale_config_validation(self, field, value, expect):
        cfg = dataclasses.replace(ScaleSimConfig(), **{field: value})
        with pytest.raises(ValueError, match=expect):
            ScaleSimulator(cfg)


# --------------------------------------------------------------------------- #
# select_fills — the one ordering rule both loops share
# --------------------------------------------------------------------------- #


class TestSelectFills:
    @settings(max_examples=30, deadline=None)
    @given(effs=st.lists(st.floats(0.0, 100.0), min_size=0, max_size=90),
           free=st.integers(0, 8))
    def test_matches_vectorized_lexsort(self, effs, free):
        picked = select_fills(effs, free)
        arr = np.asarray(effs, dtype=np.float64)
        want = np.lexsort((np.arange(len(effs)), arr))[:free]
        assert picked == list(want)
        # selected set = the `free` smallest, FIFO on ties
        assert len(picked) == min(free, len(effs))
