"""JCT / queuing-delay / throughput metrics (paper §6 evaluation)."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.job import Job, JobState


def prediction_stats(job: Job) -> Tuple[Optional[float], Optional[float]]:
    """Per-request prediction-error stats from the job's scored trace.

    Returns ``(mae, bias)`` over every ``(tokens_at, expected_remaining)``
    entry the scheduler recorded (``Job.pred_trace``), measured against the
    realised remaining length at that point — only computable once the job
    FINISHED (an aborted job's realised length is censored).  ``bias`` is
    the geometric mean of predicted/actual (1.0 = perfectly calibrated,
    < 1 = underestimates)."""
    if job.state is not JobState.FINISHED or not job.pred_trace:
        return None, None
    total = job.tokens_generated
    errs, logr = [], []
    for g, m in job.pred_trace:
        actual = total - g
        # skip degenerate entries on EITHER side: SJF records a floored
        # 0.0 estimate once a job overruns its arrival prediction, and a
        # log-ratio against that (~ -19) would collapse the request's
        # geometric-mean bias to ~0 instead of reflecting the predictor
        if actual <= 0 or m <= 0:
            continue
        errs.append(abs(m - actual))
        logr.append(np.log(m / actual))
    if not errs:
        return None, None
    return float(np.mean(errs)), float(np.exp(np.mean(logr)))


def summarize(jobs: Sequence[Job]) -> Dict[str, float]:
    """Aggregate JCT/queuing/throughput metrics over finished jobs (or
    Response records — anything with the same timing surface)."""
    if not jobs:
        # zero requests finished (all cancelled/expired): report an empty
        # but well-formed summary rather than crashing the caller
        keys = ("jct_mean", "jct_p50", "jct_p99", "jct_min", "jct_max",
                "queuing_delay_mean", "throughput_rps", "makespan",
                "ttft_mean")
        out: Dict[str, float] = {k: 0.0 for k in keys}
        out["n"] = 0
        out["preemptions"] = 0
        return out
    jcts = np.array([j.jct() for j in jobs])
    qd = np.array([j.queuing_delay for j in jobs])
    makespan = max(j.finish_time for j in jobs) - min(
        j.arrival_time for j in jobs
    )
    out = {
        "n": len(jobs),
        "jct_mean": float(jcts.mean()),
        "jct_p50": float(np.percentile(jcts, 50)),
        "jct_p99": float(np.percentile(jcts, 99)),
        "jct_min": float(jcts.min()),
        "jct_max": float(jcts.max()),
        "queuing_delay_mean": float(qd.mean()),
        "throughput_rps": len(jobs) / max(makespan, 1e-9),
        "makespan": float(makespan),
        "preemptions": int(sum(j.n_preemptions for j in jobs)),
        "ttft_mean": float(
            np.mean([
                j.first_token_time - j.arrival_time
                for j in jobs if j.first_token_time is not None
            ])
        ),
    }
    # prediction-error aggregates: present only when the records carry
    # per-request stats (Response.pred_mae / pred_bias from a
    # length-predicting policy) — raw Job summaries are unchanged
    maes = [v for j in jobs if (v := getattr(j, "pred_mae", None)) is not None]
    biases = [v for j in jobs
              if (v := getattr(j, "pred_bias", None)) is not None]
    if maes:
        out["pred_mae_mean"] = float(np.mean(maes))
    if biases:
        # geometric mean composes multiplicative per-request biases
        out["pred_bias_gmean"] = float(np.exp(np.mean(np.log(biases))))
    return out


def improvement(base: Dict[str, float], new: Dict[str, float],
                key: str = "jct_mean") -> float:
    """Percent reduction of ``key`` relative to ``base`` (paper Fig. 6)."""
    return 100.0 * (base[key] - new[key]) / base[key]
