"""Pallas flash-decode kernel: single-token attention over a long KV cache.

The decode-phase hot spot: one query token per sequence attending to a KV
cache of up to 512k entries.  The kernel blocks over the KV axis
(grid = (batch, heads, num_kv_blocks), trailing axis sequential) with online
softmax statistics in VMEM scratch — the TPU analogue of flash-decoding's
split-K, with the partial-reduction carried through sequential grid steps
instead of an inter-SM reduction pass.

Per-sequence dynamic state (valid cache length, absolute query position)
arrives via scalar prefetch (SMEM) so slots at different generation depths
batch together — exactly what ELIS's continuous batching produces.

Under a tensor-parallel mesh, :func:`flash_decode_sharded` runs the same
kernel per shard via ``shard_map`` over the TP axis: every (batch, head)
grid cell is independent (the online-softmax state is per-head), so
splitting the Q/KV head axes across devices needs no cross-device
collective and is **bit-identical** to the single-device kernel.  See
``docs/kernels.md`` for the full contract.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    kv_len_ref, q_off_ref,  # scalar-prefetch (SMEM): (B,) each
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    block_k: int,
    n_kv_blocks: int,
    window: Optional[int],
):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (1, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T) * scale  # (1, BK)
    kv_len = kv_len_ref[bi]
    q_pos = q_off_ref[bi]
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = (k_pos < kv_len) & (k_pos <= q_pos)
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _decode_kernel_int8(
    kv_len_ref, q_off_ref,  # scalar-prefetch (SMEM)
    q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    block_k: int,
    n_kv_blocks: int,
    window: Optional[int],
):
    """int8-KV variant: K/V blocks arrive quantized with per-token fp32
    scales (the §Perf serving recipe); dequantization is fused into the
    block load — HBM traffic is the int8 bytes, VMEM holds the fp32 tile."""
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (1, D)
    ksc = ks_ref[0, :].astype(jnp.float32)     # (BK,)
    vsc = vs_ref[0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ksc[:, None]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vsc[:, None]

    s = jnp.dot(q, k.T) * scale
    kv_len = kv_len_ref[bi]
    q_pos = q_off_ref[bi]
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = (k_pos < kv_len) & (k_pos <= q_pos)
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode_int8(
    q: jnp.ndarray,        # (B, 1, H, D)
    k: jnp.ndarray,        # (B, L, KH, D) int8
    v: jnp.ndarray,        # int8
    k_scale: jnp.ndarray,  # (B, L) fp32
    v_scale: jnp.ndarray,
    *,
    kv_len: jnp.ndarray,
    q_offset: jnp.ndarray,
    window: Optional[int] = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    assert sq == 1 and k.dtype == jnp.int8
    L, kh = k.shape[1], k.shape[2]
    rep = h // kh
    block_k = min(block_k, L)
    assert L % block_k == 0, (L, block_k)
    n_k = L // block_k
    scale = 1.0 / math.sqrt(d)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))

    kernel = functools.partial(
        _decode_kernel_int8, scale=scale, block_k=block_k, n_kv_blocks=n_k,
        window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ki, *_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, ki, *_: (b_, ki, h_ // rep, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, ki, *_: (b_, ki, h_ // rep, 0)),
            pl.BlockSpec((1, block_k), lambda b_, h_, ki, *_: (b_, ki)),
            pl.BlockSpec((1, block_k), lambda b_, h_, ki, *_: (b_, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, ki, *_: (b_, 0, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        interpret=interpret,
    )(kv_len, q_offset, q, k, v, k_scale, v_scale)


def flash_decode(
    q: jnp.ndarray,  # (B, 1, H, D)
    k: jnp.ndarray,  # (B, L, KH, D)
    v: jnp.ndarray,
    *,
    kv_len: jnp.ndarray,  # (B,) or scalar
    q_offset: jnp.ndarray,  # (B,) or scalar
    window: Optional[int] = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    assert sq == 1
    L, kh = k.shape[1], k.shape[2]
    rep = h // kh
    block_k = min(block_k, L)
    assert L % block_k == 0, (L, block_k)
    n_k = L // block_k
    scale = 1.0 / math.sqrt(d)

    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))

    kernel = functools.partial(
        _decode_kernel,
        scale=scale,
        block_k=block_k,
        n_kv_blocks=n_k,
        window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ki, *_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, ki, *_: (b_, ki, h_ // rep, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, ki, *_: (b_, ki, h_ // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, ki, *_: (b_, 0, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        interpret=interpret,
    )(kv_len, q_offset, q, k, v)


def flash_decode_sharded(
    q: jnp.ndarray,  # (B, 1, H, D), heads sharded on ``axis``
    k: jnp.ndarray,  # (B, L, KH, D), kv heads sharded on ``axis``
    v: jnp.ndarray,
    *,
    kv_len: jnp.ndarray,    # (B,) or scalar, replicated
    q_offset: jnp.ndarray,  # (B,) or scalar, replicated
    mesh,
    axis: str = "model",
    window: Optional[int] = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """:func:`flash_decode` under a tensor-parallel mesh.

    Wraps the kernel in ``shard_map`` over the ``axis`` mesh axis with the
    Q and KV head axes partitioned (the ``kv_shard="heads"`` slot-cache
    layout) and the slot/batch axis plus the per-slot ``kv_len`` /
    ``q_offset`` vectors replicated.  Each shard attends over its local
    KV heads only; since every (batch, head) cell of the kernel grid is
    independent, no collective runs inside the kernel and the stitched
    output is bit-identical to the single-device kernel.

    Requires both head axes divisible by the mesh-axis size so each shard
    holds whole heads at the same GQA ratio (``H/tp ÷ KH/tp = H ÷ KH``);
    indivisible layouts (KV replicated by ``sanitize_specs``) must stay on
    the XLA path — the per-shard kernel would index the wrong KV head.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, sq, h, d = q.shape
    kh = k.shape[2]
    tp = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis])
    if h % tp or kh % tp:
        raise ValueError(
            f"flash_decode_sharded: heads ({h} q / {kh} kv) must divide the "
            f"'{axis}' mesh axis of size {tp} — this layout replicates KV "
            "and must use the XLA decode path")
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))

    def local(q_, k_, v_, kv_len_, q_offset_):
        return flash_decode(q_, k_, v_, kv_len=kv_len_, q_offset=q_offset_,
                            window=window, block_k=block_k,
                            interpret=interpret)

    head_spec = P(None, None, axis, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(head_spec, head_spec, head_spec, P(None), P(None)),
        out_specs=head_spec,
        # pallas_call carries no replication rule; the output really is
        # head-sharded, so skipping the rep check is sound here
        check_rep=False,
    )(q, k, v, kv_len, q_offset)
