"""Chunked prefill + KV-offload preemption: the two recompute taxes, priced.

Two regimes on the scale simulator (trace-identical to the exact
``ELISFrontend`` loop — ``tests/test_sim_scale.py`` holds that invariant,
so these numbers transfer):

1. **Mixed-prompt regime** — 30% long prompts (600-1200 tokens) hiding in
   short-prompt interactive traffic.  Monolithic prefill makes every long
   admission a head-of-line stall for the whole node; chunked prefill
   (at most one chunk per scheduling window, interleaved with decode)
   trades a slower long-job TTFT for order-of-magnitude faster short-job
   TTFT and a lower mean JCT.  Chunking must win mean JCT at every load.
2. **Churn regime** — priority-band arrivals preempting long-context
   victims (200-600 prompt tokens, 40-200 response tokens).  Pure
   ``recompute`` re-prefills the victim's whole context on resume; the
   ``swap`` tier offloads KV to host at PCIe-ish bandwidth instead, and
   ``auto`` takes the per-victim break-even on predicted remaining
   length.  ``auto`` must beat pure recompute on mean JCT.

Emits ``BENCH_prefill_preempt.json`` at the repo root (committed).
``--smoke`` runs the CI guard on the *live* engine instead: chunked
prefill emits greedy tokens identical to one-shot prefill, and a KV
swap-out/swap-in round-trips the slot cache bit-exactly.

    PYTHONPATH=src python -m benchmarks.prefill_preempt [--smoke|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import numpy as np

from repro.core import PreemptionConfig
from repro.data.workload import ScaleWorkload
from repro.simulate.scale import ScaleSimConfig, ScaleSimulator

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_prefill_preempt.json")


# --------------------------------------------------------------------------- #
# Workloads
# --------------------------------------------------------------------------- #


def mixed_prompt_workload(n: int, seed: int, rate: float):
    """Interactive short-prompt traffic with a 30% long-prompt minority."""
    r = np.random.RandomState(seed)
    arrival = np.sort(r.uniform(0, n / rate, n))
    is_long = r.rand(n) < 0.3
    plen = np.where(is_long, r.randint(600, 1200, n), r.randint(16, 48, n))
    w = ScaleWorkload(
        arrival=arrival, length=r.randint(10, 60, n).astype(np.int64),
        prompt_len=plen.astype(np.int64),
        tenant_id=np.zeros(n, dtype=np.int32),
        priority_class=np.zeros(n, dtype=np.int16),
        deadline=np.full(n, np.inf))
    return w, is_long


def churn_workload(n: int, seed: int, rate: float) -> ScaleWorkload:
    """Long-context jobs under a stream of higher-band preemptors."""
    r = np.random.RandomState(seed)
    arrival = np.sort(r.uniform(0, n / rate, n))
    return ScaleWorkload(
        arrival=arrival, length=r.randint(40, 200, n).astype(np.int64),
        prompt_len=r.randint(200, 600, n).astype(np.int64),
        tenant_id=np.zeros(n, dtype=np.int32),
        # 30% of arrivals land in the premium band (0 outranks 1) and can
        # preempt running band-1 victims -> steady eviction churn
        priority_class=np.where(r.rand(n) < 0.3, 0, 1).astype(np.int16),
        deadline=np.full(n, np.inf))


# --------------------------------------------------------------------------- #
# Regime 1: chunked prefill on a long/short prompt mix
# --------------------------------------------------------------------------- #


def run_mixed(quick: bool) -> List[Dict]:
    n = 200 if quick else 400
    rates = (1.5,) if quick else (1.5, 2.5, 4.0)
    chunks = (None, 128) if quick else (None, 64, 128, 256)
    rows = []
    for rate in rates:
        w, is_long = mixed_prompt_workload(n, seed=1, rate=rate)
        base_jct = None
        for chunk in chunks:
            cfg = ScaleSimConfig(model="lam13", n_nodes=2, batch_size=4,
                                 window=50, seed=0, prefill_chunk=chunk)
            res = ScaleSimulator(cfg).run(w)
            ttft = res.first_token - w.arrival
            jct = float(np.nanmean(res.jct()))
            if chunk is None:
                base_jct = jct
            rows.append({
                "regime": "mixed_prompts", "rate_rps": rate,
                "prefill_chunk": chunk, "n_requests": n,
                "jct_mean_s": round(jct, 2),
                "ttft_short_mean_s": round(
                    float(np.nanmean(ttft[~is_long])), 2),
                "ttft_long_mean_s": round(
                    float(np.nanmean(ttft[is_long])), 2),
                "jct_vs_unchunked": round(jct / base_jct, 3),
                "chunking_wins": chunk is not None and jct < base_jct,
            })
    return rows


# --------------------------------------------------------------------------- #
# Regime 2: swap-vs-recompute under preemption churn
# --------------------------------------------------------------------------- #


def run_churn(quick: bool) -> List[Dict]:
    n = 150 if quick else 300
    seeds = (2,) if quick else (2, 3)
    rows = []
    for seed in seeds:
        w = churn_workload(n, seed=seed, rate=2.0)
        base_jct = None
        for pol in ("recompute", "swap", "auto"):
            cfg = ScaleSimConfig(
                model="lam13", n_nodes=2, batch_size=4, window=50, seed=0,
                preemption=PreemptionConfig(policy=pol, margin=5.0))
            res = ScaleSimulator(cfg).run(w)
            jct = float(np.nanmean(res.jct()))
            if pol == "recompute":
                base_jct = jct
            rows.append({
                "regime": "preemption_churn", "seed": seed,
                "preempt_policy": pol, "n_requests": n,
                "jct_mean_s": round(jct, 2),
                "n_preemptions": int(res.n_preemptions.sum()),
                "n_swapouts": res.n_swapouts,
                "recompute_prefill_tokens": res.recompute_prefill_tokens,
                "jct_vs_recompute": round(jct / base_jct, 3),
                "beats_recompute": pol != "recompute" and jct <= base_jct,
            })
    return rows


def run(quick: bool = False) -> List[Dict]:
    rows = run_mixed(quick) + run_churn(quick)
    # the headline claims, asserted so a cost-model regression fails loudly
    assert all(r["chunking_wins"] for r in rows
               if r["regime"] == "mixed_prompts"
               and r["prefill_chunk"] is not None), \
        "chunked prefill lost the mixed-prompt regime"
    assert all(r["beats_recompute"] for r in rows
               if r.get("preempt_policy") == "auto"), \
        "auto preempt policy lost to pure recompute under churn"
    save_results("prefill_preempt", rows)
    return rows


# --------------------------------------------------------------------------- #
# CI smoke guard (live engine)
# --------------------------------------------------------------------------- #


def smoke() -> None:
    """Live-engine guards: chunked==unchunked greedy tokens, and a KV
    swap-out/swap-in round-trips the slot cache bit-exactly."""
    import jax

    from repro.configs import get_config
    from repro.core import Job
    from repro.engine import EngineConfig, InferenceEngine
    from repro.engine.engine import _gather_slots
    from repro.models import init_params
    import jax.numpy as jnp

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=2, max_len=128, max_output=24, eos_id=-1,
                        respect_job_max=True)

    def job(i, n):
        return Job(job_id=i, prompt=f"p{i}",
                   prompt_tokens=[11 + (5 * i + k) % 60 for k in range(n)],
                   arrival_time=0.0)

    def drive(prefill_chunk):
        eng = InferenceEngine(cfg, params, ecfg)
        j = job(0, 41)
        out: List[int] = []
        for _ in range(40):
            toks, fins = eng.run_window([j], 6, prefill_chunk=prefill_chunk)
            j.generated.extend(toks[0])
            out.extend(toks[0])
            if fins[0] or j.tokens_generated >= 24:
                break
        return out, eng

    ref, _ = drive(None)
    got, eng = drive(8)
    assert got == ref, "chunked prefill diverged from one-shot greedy tokens"
    assert eng.num_chunk_dispatches >= 5, eng.num_chunk_dispatches
    assert eng.num_chunk_traces <= 2, "chunk trace explosion"

    # swap-out -> swap-in keeps the victim's KV bit-exact and its decode
    # stream identical to an uninterrupted run
    eng = InferenceEngine(cfg, params, ecfg)
    j0, j1 = job(3, 9), job(4, 7)
    toks, _ = eng.run_window([j0, j1], 5)
    j0.generated.extend(toks[0])
    j1.generated.extend(toks[1])
    slot = eng.slot_of[j0.job_id]
    before = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([slot], jnp.int32)))
    assert eng.offload_job(j0.job_id) and eng.has_stash(j0.job_id)
    toks, _ = eng.run_window([j1], 5)               # j1 decodes while j0 is out
    j1.generated.extend(toks[0])
    new_slot = eng.restore_job(j0)
    after = jax.device_get(
        _gather_slots(eng.cache, jnp.asarray([new_slot], jnp.int32)))
    leaves_b = jax.tree_util.tree_leaves(before)
    leaves_a = jax.tree_util.tree_leaves(after)
    assert all(np.array_equal(a, b) for a, b in zip(leaves_a, leaves_b)), \
        "swap round-trip is not bit-exact"
    # restored j0 continues exactly where an uninterrupted engine would
    ref_eng = InferenceEngine(cfg, params, ecfg)
    rj = job(3, 9)
    rt, _ = ref_eng.run_window([rj], 5)
    rj.generated.extend(rt[0])
    rt, _ = ref_eng.run_window([rj], 5)
    toks, _ = eng.run_window([j0, j1], 5)
    assert toks[0] == rt[0], \
        "post-restore decode diverged from uninterrupted run"
    print("prefill_preempt smoke: OK (chunked==one-shot greedy, "
          "swap round-trip bit-exact)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: live-engine chunk identity + swap "
                         "round-trip bit-exactness")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        rows = run(quick=args.quick)
        for r in rows:
            print(r)
        if not args.quick:
            with open(ROOT_JSON, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"wrote {ROOT_JSON}")
