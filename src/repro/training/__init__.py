from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    schedule_lr,
)
from repro.training.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.loop import make_train_step, train

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "latest_step",
    "make_train_step",
    "restore_checkpoint",
    "save_checkpoint",
    "schedule_lr",
    "train",
]
