"""Scheduling policies: FCFS, SJF (oracle one-shot), ISRTF (the paper's
contribution), and MLFQ (FastServe-style, for comparison).

A policy assigns each job a *priority* — smaller runs earlier.  ISRTF
re-predicts the remaining length every scheduling iteration (Algorithm 1
lines 11–14) through the distribution-aware
:func:`repro.core.predictor.predict_lengths` entry point; with
``SchedulerConfig.risk_quantile`` set it ranks on a calibrated upper
quantile of each :class:`~repro.core.predictor.LengthPrediction` instead
of the point estimate (risk-aware ISRTF — hedging against underestimates,
the head-of-line-blocking direction).

This module owns the whole scoring pipeline:

* :func:`score_pool` — ONE fused scoring pass per scheduling window over
  ``running + waiting`` (a single batched predictor dispatch when the
  predictor supports :meth:`~repro.core.predictor.BGEPredictor.predict_jobs`),
  split back into per-queue effective priorities by the caller;
* :func:`effective_priority` — the single source of truth for
  priority-class banding and anti-starvation aging (an aging term subtracts
  ``aging_rate * wait_seconds`` so long-waiting jobs eventually run
  regardless of length — paper §3.4);
* ``SchedulerConfig.repredict_every`` — ALISE-style prediction staleness:
  between full re-scores a job reuses its cached prediction minus the
  tokens it generated since it was last scored, so the encoder runs on a
  configurable cadence instead of every window.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.job import Job
from repro.core.predictor import (
    LengthPrediction,
    Predictor,
    predict_lengths,
)


@dataclass
class SchedulerConfig:
    policy: str = "isrtf"  # fcfs | sjf | isrtf | mlfq
    #: tokens per scheduling iteration (paper: 50)
    window: int = 50
    #: max jobs per backend batch
    batch_size: int = 4
    #: aging: priority units (tokens) forgiven per second of waiting; 0 = off
    aging_rate: float = 0.0
    #: MLFQ quantum boundaries in generated tokens
    mlfq_levels: Tuple[int, ...] = (50, 200, 800)
    #: risk-aware ISRTF: rank on this calibrated upper quantile of the
    #: predicted remaining length instead of the point estimate — hedging
    #: against underestimates, which are the expensive direction (a long
    #: job predicted short runs early and head-of-line-blocks the truly
    #: short ones).  None = the paper's Algorithm 1 (rank on the mean);
    #: bit-identical traces to the scalar-predictor era.  Only policies
    #: that re-predict (ISRTF) consume it; the cluster layer's
    #: predicted-work accounting always uses the expectation, never the
    #: quantile (see ``cached_expected_remaining``).
    risk_quantile: Optional[float] = None
    #: run the length predictor every N scheduling windows (per node); in
    #: between, a job's cached prediction is decayed by the tokens it has
    #: generated since it was scored (ALISE-style staleness).  1 = the
    #: paper's Algorithm 1 (re-predict every window).  Only policies that
    #: re-predict (ISRTF) are affected; newly arrived jobs are always
    #: scored on first sight regardless of the stride.
    repredict_every: int = 1
    #: chunked prefill: split prompt ingestion into chunks of this many
    #: tokens, at most one chunk per scheduling window, interleaved with
    #: the running decodes (Sarathi-style stall removal — a long prompt no
    #: longer freezes every decode for a full window).  None = one-shot
    #: prefill (the pre-chunking behaviour, bit-compatible).  When set,
    #: ISRTF ranks partially-prefilled jobs by *total* remaining work:
    #: predicted remaining output plus the unprefilled prompt tail
    #: (:func:`prefill_debt`).
    prefill_chunk: Optional[int] = None
    #: what a re-predicting policy ORDERS the pool by: ``"magnitude"`` (the
    #: calibrated mean, or its ``risk_quantile``) or ``"rank_score"`` (the
    #: learning-to-rank head's score — ISRTF only needs the order of
    #: remaining lengths, and a head trained to rank beats the point
    #: regressor at exactly that).  Requires predictions carrying
    #: :attr:`~repro.core.predictor.LengthPrediction.rank_score` (a ranked
    #: predictor).  Either way ``Job.expected_remaining`` and all cluster
    #: predicted-work accounting stay on the calibrated mean — rank scores
    #: never leak into load totals (see ``cached_expected_remaining``).
    rank_by: str = "magnitude"


class Policy:
    """Base: FCFS."""

    name = "fcfs"
    #: True when the policy calls the predictor anew every window (ISRTF);
    #: such policies may reuse stale predictions between full re-scores
    repredicts = False
    #: True when ``priority`` is a predicted remaining *length* in tokens —
    #: only then do priorities feed the cluster layer's predicted-work
    #: accounting (FCFS/MLFQ priorities are timestamps/levels, not work)
    predicts_length = False

    def __init__(self, cfg: SchedulerConfig, predictor: Optional[Predictor]):
        self.cfg = cfg
        self.predictor = predictor

    def priority(self, job: Job, now: float) -> float:
        return job.arrival_time


class FCFSPolicy(Policy):
    name = "fcfs"


class SJFPolicy(Policy):
    """One-shot shortest-job-first: predict once at arrival, never update
    (Qiu et al. / the paper's oracle baseline when given OraclePredictor)."""

    name = "sjf"
    predicts_length = True

    def priority(self, job: Job, now: float) -> float:
        if job.priority is None:
            return float(self.predictor.init(job))
        # keep the arrival-time estimate: total predicted length minus
        # whatever has already been generated
        first = job.predictions[0] if job.predictions else job.priority
        return max(float(first) - job.tokens_generated, 0.0)


class ISRTFPolicy(Policy):
    """Iterative shortest-remaining-time-first (the paper's scheduler)."""

    name = "isrtf"
    repredicts = True
    predicts_length = True

    def priority(self, job: Job, now: float) -> float:
        if job.priority is None:
            return float(self.predictor.init(job))
        return float(self.predictor.iter(job))


class MLFQPolicy(Policy):
    """FastServe-style multi-level feedback queue on service received."""

    name = "mlfq"

    def priority(self, job: Job, now: float) -> float:
        level = 0
        for bound in self.cfg.mlfq_levels:
            if job.tokens_generated >= bound:
                level += 1
        # within a level, FCFS
        return level * 1e9 + job.arrival_time


POLICIES = {
    "fcfs": FCFSPolicy,
    "sjf": SJFPolicy,
    "isrtf": ISRTFPolicy,
    "mlfq": MLFQPolicy,
}

#: valid pool-ordering sources for re-predicting policies
RANK_BY = ("magnitude", "rank_score")


def make_policy(cfg: SchedulerConfig, predictor: Optional[Predictor]) -> Policy:
    try:
        cls = POLICIES[cfg.policy]
    except KeyError:
        raise ValueError(f"unknown policy {cfg.policy!r}") from None
    if cls in (SJFPolicy, ISRTFPolicy) and predictor is None:
        raise ValueError(f"{cfg.policy} requires a predictor")
    if cfg.rank_by not in RANK_BY:
        raise ValueError(
            f"unknown rank_by {cfg.rank_by!r} (choose one of {RANK_BY})")
    if cfg.rank_by == "rank_score" and cfg.risk_quantile is not None:
        raise ValueError(
            "rank_by='rank_score' and risk_quantile are mutually exclusive: "
            "the ranking head orders the pool directly, quantiles order "
            "magnitudes — pick one")
    return cls(cfg, predictor)


# --------------------------------------------------------------------------- #
# Scoring pipeline (Algorithm 1 lines 11–14, fused + strided)
# --------------------------------------------------------------------------- #


#: effective-priority penalty per priority class — large enough that class
#: bands never interleave for any realistic predicted length (tokens)
PRIORITY_CLASS_WEIGHT = 1e7


def effective_priority(cfg: SchedulerConfig, job: Job, raw: float,
                       now: float) -> float:
    """Raw priority -> effective priority: priority-class banding plus the
    anti-starvation aging credit.  The single implementation — both the
    frontend's batch path and any per-job caller go through here."""
    eff = raw + job.priority_class * PRIORITY_CLASS_WEIGHT
    if cfg.aging_rate > 0 and job.last_enqueue_time is not None:
        eff -= cfg.aging_rate * max(now - job.last_enqueue_time, 0.0)
    return eff


def prefill_debt(cfg: SchedulerConfig, job: Job) -> float:
    """Context tokens the backend still has to materialise before ``job``
    can decode: ``prompt + generated - prefilled``.  Zero whenever chunked
    prefill is off (``cfg.prefill_chunk is None``) so legacy traces are
    untouched; with chunking on, this is the unprefilled prompt tail for a
    mid-prefill job and the full context for a recompute-evicted one.
    Added to the *raw* priority at ranking time (never stored in
    ``job.priority`` — predictions stay pure remaining-output estimates)."""
    if cfg.prefill_chunk is None:
        return 0.0
    return float(max(
        len(job.prompt_tokens) + job.tokens_generated - job.prefilled_tokens,
        0))


def _rank_scores(preds: Sequence[LengthPrediction]) -> List[float]:
    """Pool ordering from the ranking head — loud when it isn't there."""
    out = []
    for p in preds:
        if p.rank_score is None:
            raise ValueError(
                "rank_by='rank_score' needs predictions carrying a "
                "rank_score — use a two-head ranked predictor "
                "(make_predictor('ranked', bge=...)); this predictor "
                "returned none")
        out.append(float(p.rank_score))
    return out


def score_jobs(policy: Policy, jobs: Sequence[Job], now: float) -> List[float]:
    """Fresh raw priorities for ``jobs`` — at most ONE predictor dispatch
    (batched through :func:`~repro.core.predictor.predict_lengths`, the
    distribution-aware entry point).  A re-predicting policy ranks on the
    point estimate, or — with ``SchedulerConfig.risk_quantile`` set — on
    that calibrated upper quantile of each :class:`LengthPrediction`.

    Records each score on the job: ``priority`` (the value ranked on), the
    ``predictions`` history (one entry per scored window), the staleness
    watermark ``tokens_at_last_score``, and — for length-predicting
    policies — ``expected_remaining`` (always the expectation, which is
    what the cluster layer's predicted-work accounting consumes) plus the
    ``pred_trace`` used for per-request prediction-error stats."""
    if not jobs:
        return []
    pred = policy.predictor
    if policy.repredicts and pred is not None:
        preds = predict_lengths(pred, jobs)
        q = policy.cfg.risk_quantile
        if policy.cfg.rank_by == "rank_score":
            raw = _rank_scores(preds)
        elif q is None:
            raw = [p.mean for p in preds]
        else:
            raw = [p.quantile(q) for p in preds]
        means = [p.mean for p in preds]
    else:
        raw = [policy.priority(j, now) for j in jobs]
        means = raw
    for j, p, m in zip(jobs, raw, means):
        j.priority = p
        j.predictions.append(p)
        j.tokens_at_last_score = j.tokens_generated
        if policy.predicts_length:
            j.expected_remaining = m
            j.pred_trace.append((j.tokens_generated, m))
    return raw


def cached_raw_priority(job: Job) -> float:
    """The raw priority the current window's scoring pass used for ``job``:
    its cached prediction decayed by the tokens generated since it was last
    scored.  Right after a fresh score the decay is zero, so this is exact
    on full re-score windows and matches the stale-window reuse otherwise."""
    if job.tokens_at_last_score is None:
        return float(job.priority)
    return max(float(job.priority)
               - (job.tokens_generated - job.tokens_at_last_score), 0.0)


def cached_expected_remaining(job: Job) -> float:
    """The job's *expected* remaining length (progress-decayed), for the
    cluster layer's predicted-work accounting.  Identical to
    :func:`cached_raw_priority` when no risk quantile is set (the scoring
    value IS the expectation then); with risk-aware scoring the priority is
    an upper quantile, and balancing load on a sum of upper quantiles would
    systematically over-count — work accounting stays on the mean."""
    base = (job.expected_remaining if job.expected_remaining is not None
            else job.priority)
    if job.tokens_at_last_score is None:
        return float(base)
    return max(float(base)
               - (job.tokens_generated - job.tokens_at_last_score), 0.0)


def batch_effective(policy: Policy, jobs: Sequence[Job],
                    now: float) -> List[float]:
    """Score ``jobs`` fresh and return effective priorities (one fused
    predictor dispatch; see :func:`score_jobs`)."""
    raw = score_jobs(policy, jobs, now)
    return [effective_priority(policy.cfg, j, p, now)
            for j, p in zip(jobs, raw)]


def score_pool(policy: Policy, running: Sequence[Job], waiting: Sequence[Job],
               now: float, *, full: bool = True
               ) -> Tuple[List[float], List[float]]:
    """One fused scoring pass over a node's whole pool.

    Scores ``running + waiting`` in a single :func:`score_jobs` call — one
    predictor dispatch per scheduling window instead of two — and splits the
    effective priorities back into ``(run_eff, wait_eff)``.

    With ``full=False`` (a stride window between full re-scores, see
    ``SchedulerConfig.repredict_every``) a re-predicting policy reuses each
    job's cached prediction decayed by the tokens generated since it was
    scored; jobs that were never scored (new arrivals) still get a fresh,
    batched prediction.  Non-repredicting policies always score fresh —
    their ``priority`` is O(1) and must track arrival order / service level.
    """
    pool = list(running) + list(waiting)
    if full or not policy.repredicts:
        raw = score_jobs(policy, pool, now)
    else:
        fresh = [j for j in pool
                 if j.priority is None or j.tokens_at_last_score is None]
        fresh_raw = {id(j): p
                     for j, p in zip(fresh, score_jobs(policy, fresh, now))}
        raw = [fresh_raw[id(j)] if id(j) in fresh_raw
               else cached_raw_priority(j) for j in pool]
    eff = [effective_priority(policy.cfg, j, p + prefill_debt(policy.cfg, j),
                              now)
           for j, p in zip(pool, raw)]
    return eff[: len(running)], eff[len(running):]


# --------------------------------------------------------------------------- #
# PriorityBuffer (paper §4.1: one priority queue per backend node)
# --------------------------------------------------------------------------- #


class PriorityBuffer:
    def __init__(self):
        self._heaps: Dict[int, List] = {}
        self._count = itertools.count()

    def push(self, node: int, prio: float, job: Job) -> None:
        heapq.heappush(self._heaps.setdefault(node, []),
                       (prio, next(self._count), job))

    def pop_batch(self, node: int, k: int) -> List[Job]:
        heap = self._heaps.get(node, [])
        out = []
        while heap and len(out) < k:
            out.append(heapq.heappop(heap)[2])
        return out

    def depth(self, node: int) -> int:
        return len(self._heaps.get(node, []))


# --------------------------------------------------------------------------- #
# Preemption (paper §3.4 / Appendix A)
# --------------------------------------------------------------------------- #


@dataclass
class PreemptionConfig:
    """Knobs for 'adjusting the frequency of preemption' (paper §1, §3.4)."""

    enabled: bool = True
    #: a waiting job must beat a running job's priority by this many tokens
    #: (paper §3.4: preemption should be rare; one window's worth of tokens)
    margin: float = 50.0
    #: at most this fraction of a batch may be preempted per iteration
    max_fraction: float = 0.25
    #: per-preemption cost charged when the victim resumes (KV recompute),
    #: expressed in prompt-tokens re-prefilled
    recompute_tokens: bool = True
    #: what happens to a victim's KV cache (ALISE, arXiv 2410.23537):
    #: ``recompute`` discards it (resume pays a full re-prefill — the
    #: pre-offload behaviour), ``swap`` copies it to host memory and back,
    #: ``auto`` picks per victim via the :func:`decide_preempt` break-even
    #: on the backend's (swap_s, recompute_s) estimates and the victim's
    #: predicted remaining length
    policy: str = "recompute"
    #: ``auto`` penalty per predicted-remaining token for *holding* a
    #: swapped cache in host memory — a job expected to run long after
    #: resume ties up host KV (and risks a second swap) longer, so the
    #: break-even tilts toward recompute for it
    swap_hold_s_per_token: float = 1e-3
    #: watermark (in stashed context tokens) bounding the live engine's
    #: host swap pool.  When a new swap-out would push the pool past the
    #: watermark, the COLDEST stashed victims (oldest swap-outs) are
    #: evicted to the recompute-fallback path with a loud once-per-engine
    #: warning; if the fresh stash alone exceeds the pool it is refused
    #: and the victim recomputes.  None = unbounded (the pre-watermark
    #: behaviour).  Threaded onto each engine by ``EngineExecutor``.
    swap_pool_tokens: Optional[int] = None


PREEMPT_POLICIES = ("recompute", "swap", "auto")


def decide_preempt(cfg: PreemptionConfig,
                   costs: Optional[Tuple[float, float]],
                   predicted_remaining: Optional[float]) -> str:
    """Resolve a victim's preemption treatment to ``"swap"`` or
    ``"recompute"``.  ``costs`` is the backend's ``(swap_round_trip_s,
    recompute_s)`` estimate (None = backend can't price it → recompute);
    ``predicted_remaining`` feeds the hold-cost term under ``auto``."""
    if cfg.policy not in PREEMPT_POLICIES:
        raise ValueError(
            f"unknown preempt policy {cfg.policy!r}; "
            f"choose one of {PREEMPT_POLICIES}")
    if cfg.policy != "auto":
        return cfg.policy
    if costs is None:
        return "recompute"
    swap_s, rec_s = costs
    r_hat = max(float(predicted_remaining or 0.0), 0.0)
    return ("swap"
            if swap_s + cfg.swap_hold_s_per_token * r_hat < rec_s
            else "recompute")


def select_fills(waiting_eff: Sequence[float], free: int) -> List[int]:
    """Indices into a waiting queue to dispatch into ``free`` slots,
    best-first: ordered by (effective priority, queue position) — queue
    position breaks ties so equal-priority jobs dispatch in enqueue order.

    The single fill-selection rule, shared by the exact event loop
    (``ELISFrontend._form_batch``) and the vectorized fast path
    (``repro.simulate.scale``) so the two can never drift."""
    if free <= 0 or not waiting_eff:
        return []
    order = sorted(range(len(waiting_eff)),
                   key=lambda k: (waiting_eff[k], k))
    return order[:free]


def select_preemptions(
    running: Sequence[Tuple[float, Job]],
    waiting: Sequence[Tuple[float, Job]],
    cfg: PreemptionConfig,
) -> List[Tuple[Job, Job]]:
    """Given (priority, job) for the running batch and the waiting queue,
    return [(victim, replacement), ...] — lowest-priority running jobs are
    displaced by strictly-higher-priority waiters (vLLM's priority preemption
    with our margin/frequency knobs)."""
    if not cfg.enabled or not running or not waiting:
        return []
    # ceiling, not floor: int() would zero the budget for any running batch
    # of <= 1/max_fraction jobs, silently disabling preemption at small
    # batch sizes (e.g. <= 3 running at the default 0.25); an enabled
    # policy with a positive fraction can always displace one victim
    budget = math.ceil(len(running) * cfg.max_fraction)
    victims = sorted(running, key=lambda t: -t[0])  # worst running first
    claimants = sorted(waiting, key=lambda t: t[0])  # best waiting first
    swaps: List[Tuple[Job, Job]] = []
    for (rp, rjob), (wp, wjob) in zip(victims, claimants):
        if len(swaps) >= budget:
            break
        if wp + cfg.margin < rp:
            swaps.append((rjob, wjob))
    return swaps
