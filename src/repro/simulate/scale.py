"""Scale-out traffic simulation: the vectorized window-synchronous fast path.

:class:`repro.core.frontend.ELISFrontend` + :class:`SimExecutor` replay the
cluster one heap event at a time with full ``Job`` objects and token
streams — exact, but ~10k requests/minute.  This module re-implements the
*same semantics* over a trace-compressed :class:`~repro.data.workload.
ScaleWorkload` (struct-of-arrays: one numpy row per request, no Job
objects, no token streams) so million-request scenario sweeps run in
minutes on a laptop CPU:

* the event loop keeps only three event sources — the sorted arrival
  array, the pre-sorted deadline events, and one boundary heap entry per
  node — and advances each node window-synchronously: the whole
  score → preempt → fill → execute → apply pipeline of one scheduling
  window is a handful of numpy calls over the node's pool;
* scoring, banding and aging are computed vectorized but in the *same
  IEEE op order* as the exact loop (elementwise ops are order-free; the
  order-sensitive accumulations — prefill, predicted-work deltas, the
  batch apply — run sequentially in batch order, which is O(batch), not
  O(queue));
* stochastic predictions reuse the exact loop's RNG stream:
  ``RandomState.lognormal`` with array parameters consumes the underlying
  gauss stream element-by-element, identically to the per-job scalar
  draws of :class:`~repro.core.predictor.NoisyOraclePredictor`;
* when a node's waiting queue is empty, no running job has a deadline,
  and no global arrival lands before a window's start, the loop
  *coalesces* up to ``(min_remaining - 1) // window`` whole windows into
  one step — the per-window durations are still accumulated sequentially
  (``end += duration``), so the virtual clock is bit-identical.

Exactness contract (property-tested in ``tests/test_sim_scale.py``):

* ``predictor="oracle"`` — trace-identical to the exact loop for every
  supported config (fcfs/sjf/isrtf x preemption x aging x priority
  classes x deadlines x placements x heterogeneous nodes), including
  with coalescing: all scores are integer-valued, so skipped scoring
  passes and single-shot work decay are bit-neutral;
* ``predictor="noisy_oracle"`` — trace-identical with coalescing off
  (every scoring pass then draws the same RNG sequence as the exact
  loop); with coalescing on, ISRTF's skipped per-window draws shift the
  stream, so the run is *statistically* equivalent instead (the
  benchmark reports the fidelity delta).  Coalescing therefore
  auto-disables whenever it would change the draw sequence or
  non-integer work accounting.

Everything the exact loop treats as irregular — ``cancel``, rebalancing
(work-stealing), MLFQ, BGE predictors, risk quantiles — is out of scope
here by design: :meth:`ScaleSimConfig.validate` fails loudly and points
back to :func:`repro.simulate.runner.run_experiment`.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.metrics import StreamingSummary, fairness_ratio
from repro.core.scheduler import (
    PREEMPT_POLICIES,
    PRIORITY_CLASS_WEIGHT,
    RANK_BY,
    PreemptionConfig,
    decide_preempt,
    select_fills,
    select_preemptions,
)
from repro.core.load_balancer import PLACEMENTS
from repro.data.workload import ScaleWorkload
from repro.simulate.profiles import PROFILES, SCHED_OVERHEAD_MS, ModelProfile

__all__ = [
    "ScaleSimConfig", "ScaleSimulator", "ScaleResult",
    "run_exact_reference",
]

#: job lifecycle codes in ``ScaleResult.state``
UNARRIVED, WAITING, RUNNING, FINISHED, EXPIRED = 0, 1, 2, 3, 4

_POLICIES = ("fcfs", "sjf", "isrtf")
_PREDICTORS = ("oracle", "noisy_oracle")

#: queue size beyond which selection switches from the shared Python
#: rules to their numpy equivalents (proven identical; see tests)
_VECTOR_CUTOVER = 64


def _resolve_profile(p) -> ModelProfile:
    """Registry name or a ModelProfile instance (live-calibrated fits)."""
    return p if isinstance(p, ModelProfile) else PROFILES[p]


@dataclass
class ScaleSimConfig:
    """Configuration of one fast-path run (mirrors the exact loop's
    ``ExperimentConfig``/``FrontendConfig`` surface for the supported
    subset)."""

    #: profile name in ``PROFILES`` — or a :class:`ModelProfile` instance
    #: (live-calibrated fits from ``EngineExecutor.calibrated_profile()``
    #: plug in directly; the live↔sim loop never round-trips a registry)
    model: object = "vic"
    policy: str = "isrtf"            # fcfs | sjf | isrtf
    predictor: str = "oracle"        # oracle | noisy_oracle
    n_nodes: int = 1
    batch_size: int = 4
    window: int = 50
    aging_rate: float = 0.0
    repredict_every: int = 1
    preemption: PreemptionConfig = field(default_factory=PreemptionConfig)
    placement: str = "least_jobs"
    seed: int = 0
    hw_speedup: float = 1.0
    #: heterogeneous clusters: node id -> profile name or ModelProfile
    #: instance (others run ``model``)
    node_profiles: Optional[Dict[int, object]] = None
    #: per-window scheduling overhead [s]; None = the paper-calibrated
    #: ``SCHED_OVERHEAD_MS`` (live replays pass the fitted intercept)
    sched_overhead_s: Optional[float] = None
    #: systematic multiplicative mis-calibration of the noisy oracle
    predictor_bias: float = 1.0
    #: window coalescing on idle-queue nodes; auto-disabled whenever it
    #: would change the RNG draw sequence or non-integer work accounting
    coalesce: bool = True
    #: finished records buffered between streaming-metrics flushes
    flush_every: int = 8192
    #: chunked prefill (SchedulerConfig.prefill_chunk mirror): at most one
    #: batch-1 prefill chunk of this many tokens per window, decode runs
    #: only the prefill-complete sub-batch.  None = one-shot prefill.
    #: Coalescing auto-disables when set (a mid-prefill job breaks the
    #: all-jobs-decode invariant coalescing relies on).
    prefill_chunk: Optional[int] = None
    #: host<->device KV copy model for ``preemption.policy`` swap/auto
    #: (SimExecutor mirror)
    swap_bandwidth_bytes_s: float = 16e9
    swap_latency_s: float = 0.0005
    #: pool-ordering source (SchedulerConfig.rank_by mirror).  The fast
    #: path only supports "magnitude": rank scores come from the two-head
    #: BGE predictor, which is exact-loop-only (see ``_PREDICTORS``)
    rank_by: str = "magnitude"

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1 or None, got {self.prefill_chunk}")
        if self.preemption.policy not in PREEMPT_POLICIES:
            raise ValueError(
                f"unknown preempt policy {self.preemption.policy!r}; "
                f"choose one of {PREEMPT_POLICIES}")
        if (not isinstance(self.model, ModelProfile)
                and self.model not in PROFILES):
            raise ValueError(f"unknown model {self.model!r} "
                             f"(have {sorted(PROFILES)})")
        for node, name in (self.node_profiles or {}).items():
            if not isinstance(name, ModelProfile) and name not in PROFILES:
                raise ValueError(f"unknown profile {name!r} for node {node} "
                                 f"(have {sorted(PROFILES)})")
        if self.policy not in _POLICIES:
            raise ValueError(
                f"unknown/unsupported policy {self.policy!r} for the scale "
                f"fast path (have {list(_POLICIES)}); mlfq and other "
                "irregular policies run through "
                "repro.simulate.runner.run_experiment")
        if self.predictor not in _PREDICTORS:
            raise ValueError(
                f"unknown/unsupported predictor {self.predictor!r} for the "
                f"scale fast path (have {list(_PREDICTORS)}); bge/calibrated "
                "predictors run through repro.simulate.runner.run_experiment")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {self.placement!r} "
                             f"(have {sorted(PLACEMENTS)})")
        if self.rank_by not in RANK_BY:
            raise ValueError(
                f"unknown rank_by {self.rank_by!r} (choose one of {RANK_BY})")
        if self.rank_by == "rank_score":
            raise ValueError(
                "rank_by='rank_score' needs the two-head ranked (bge) "
                "predictor, which the scale fast path does not support — "
                "run through repro.simulate.runner.run_experiment")
        if self.n_nodes < 1 or self.batch_size < 1 or self.window < 1:
            raise ValueError("n_nodes, batch_size and window must be >= 1")

    # ------------------------------------------------------------------ #
    def profiles(self) -> List[ModelProfile]:
        """Per-node calibrated profiles (scaled by ``hw_speedup``)."""
        over = self.node_profiles or {}
        return [_resolve_profile(over.get(n, self.model))
                .scaled(self.hw_speedup) for n in range(self.n_nodes)]


@dataclass
class ScaleResult:
    """Per-job outcome arrays plus the streamed per-tenant summaries."""

    cfg: ScaleSimConfig
    workload: ScaleWorkload
    state: np.ndarray          # int8 lifecycle codes (FINISHED/EXPIRED/...)
    finish: np.ndarray         # float64; NaN when never terminal
    first_token: np.ndarray    # float64; NaN when never dispatched
    queuing_delay: np.ndarray  # float64 cumulative queue time
    n_preemptions: np.ndarray  # int64
    n_iterations: np.ndarray   # int64 scheduling windows participated in
    finished_order: np.ndarray  # int64 job ids in finish order
    tenant_summaries: Dict[str, StreamingSummary]
    n_windows: int
    n_coalesced: int
    wall_s: float
    n_swapouts: int = 0
    n_swapins: int = 0
    #: context tokens re-prefilled by recompute-on-resume (SimExecutor's
    #: ``recompute_prefill_tokens`` mirror)
    recompute_prefill_tokens: int = 0

    def jct(self) -> np.ndarray:
        """Finished jobs' completion times (NaN elsewhere)."""
        out = self.finish - self.workload.arrival
        out[self.state != FINISHED] = np.nan
        return out

    def metrics(self) -> Dict[str, object]:
        """Aggregate + per-tenant summary dict (streaming quantiles)."""
        g = StreamingSummary()
        for s in self.tenant_summaries.values():
            g.merge(s)
        out: Dict[str, object] = g.summarize()
        out["tenants"] = {t: s.summarize()
                          for t, s in sorted(self.tenant_summaries.items())}
        # deadline-heavy scenarios (agent): expiry is a per-tenant outcome —
        # streamed summaries only see *finished* jobs, so count from the
        # lifecycle arrays
        tid = self.workload.tenant_id
        for ti, t in enumerate(self.workload.tenants):
            mask = tid == ti
            n_t = int(mask.sum())
            if n_t and t in out["tenants"]:
                out["tenants"][t]["n_submitted"] = n_t
                out["tenants"][t]["expiry_rate"] = round(
                    float((self.state[mask] == EXPIRED).sum()) / n_t, 4)
        out["fairness_jct"] = fairness_ratio(
            {t: s.sketch.mean for t, s in self.tenant_summaries.items()})
        out["n_finished"] = int((self.state == FINISHED).sum())
        out["n_expired"] = int((self.state == EXPIRED).sum())
        out["n_windows"] = self.n_windows
        out["n_coalesced_windows"] = self.n_coalesced
        out["n_swapouts"] = self.n_swapouts
        out["n_swapins"] = self.n_swapins
        out["recompute_prefill_tokens"] = self.recompute_prefill_tokens
        out["wall_s"] = self.wall_s
        out["requests_per_s"] = (self.workload.n / self.wall_s
                                 if self.wall_s > 0 else 0.0)
        return out


# --------------------------------------------------------------------------- #


class ScaleSimulator:
    """The vectorized window-synchronous event loop (see module docs)."""

    def __init__(self, cfg: ScaleSimConfig):
        cfg.validate()
        self.cfg = cfg
        self._profiles = cfg.profiles()
        #: seconds per generated token per node (= SimExecutor.node_token_cost)
        self._cost = [p.decode_ms_1 / 1000.0 for p in self._profiles]
        self._track_work = PLACEMENTS[cfg.placement].uses_work
        self._predicts_length = cfg.policy in ("sjf", "isrtf")
        noisy = cfg.predictor == "noisy_oracle"
        # coalescing skips per-window scoring passes; that is bit-neutral
        # only when those passes draw no RNG (oracle, or noisy under a
        # non-repredicting policy) AND the skipped predicted-work refreshes
        # are integer-valued (oracle) or absent (no work tracking)
        self._coalesce = cfg.coalesce and (
            not noisy or (cfg.policy != "isrtf" and not self._track_work)
        ) and cfg.prefill_chunk is None

    # ------------------------------------------------------------------ #
    def run(self, w: ScaleWorkload) -> ScaleResult:
        cfg = self.cfg
        t0 = time.perf_counter()
        n = w.n
        n_nodes = cfg.n_nodes
        window = cfg.window
        cap = cfg.batch_size
        policy = cfg.policy
        isrtf = policy == "isrtf"
        sjf = policy == "sjf"
        noisy = cfg.predictor == "noisy_oracle"
        bias = cfg.predictor_bias
        aging = cfg.aging_rate
        stride = max(cfg.repredict_every, 1)
        pcfg = cfg.preemption
        chunk = cfg.prefill_chunk
        chunked = chunk is not None
        swap_policy = pcfg.policy != "recompute"
        swap_lat = cfg.swap_latency_s
        swap_bw = cfg.swap_bandwidth_bytes_s
        track_work = self._track_work
        refresh_work = track_work and self._predicts_length
        placement = cfg.placement
        coalesce = self._coalesce
        overhead = (cfg.sched_overhead_s if cfg.sched_overhead_s is not None
                    else SCHED_OVERHEAD_MS / 1000.0)
        INF = math.inf

        arrival = np.ascontiguousarray(w.arrival, dtype=np.float64)
        length = np.ascontiguousarray(w.length, dtype=np.int64)
        plen = np.ascontiguousarray(w.prompt_len, dtype=np.int64)
        band = w.priority_class.astype(np.float64) * PRIORITY_CLASS_WEIGHT
        deadline = np.ascontiguousarray(w.deadline, dtype=np.float64)
        has_deadlines = bool(np.isfinite(deadline).any())

        # per-job state (struct of arrays)
        gen = np.zeros(n, dtype=np.int64)
        state = np.zeros(n, dtype=np.int8)
        node_of = np.full(n, -1, dtype=np.int32)
        last_enq = np.full(n, np.nan)
        qdelay = np.zeros(n)
        first_tok = np.full(n, np.nan)
        finish = np.full(n, np.nan)
        npre = np.zeros(n, dtype=np.int64)
        niter = np.zeros(n, dtype=np.int64)
        resident = np.zeros(n, dtype=bool)
        pref = np.zeros(n, dtype=np.int64)   # Job.prefilled_tokens mirror
        swapped = np.zeros(n, dtype=bool)    # KV stashed in host memory
        n_swapouts = 0
        n_swapins = 0
        recompute_toks = 0
        workv = np.zeros(n)          # GlobalState._job_work mirror
        # prediction caches (repredict_every stride; noisy ISRTF only —
        # oracle scores are reproducible from (length, gen) at any time)
        prio_cache = np.zeros(n)
        gen_at = np.zeros(n, dtype=np.int64)
        scored = np.zeros(n, dtype=bool)
        sjf_first = np.full(n, np.nan)

        rng: Optional[np.random.RandomState] = None
        sigma_tab = mu_tab = None
        if noisy:
            from repro.core.predictor import NoisyOraclePredictor
            from repro.data.dataset import WINDOW as pred_window
            # same seed derivation as run_experiment / run_exact_reference
            rng = np.random.RandomState(cfg.seed + 1)
            s0, dec, fl = (NoisyOraclePredictor.sigma0,
                           NoisyOraclePredictor.decay,
                           NoisyOraclePredictor.sigma_floor)
            kmax = int(length.max()) // pred_window + 2 if n else 1
            # python pow, like NoisyOraclePredictor._sigma — not np.power
            sigma_tab = np.array([max(s0 * dec ** k, fl)
                                  for k in range(kmax + 1)])
            mu_tab = -0.5 * sigma_tab * sigma_tab
            pred_step_window = pred_window
        else:
            pred_step_window = 0  # unused

        # per-node structures (GlobalState + ELISFrontend queue mirrors)
        waitq: List[List[int]] = [[] for _ in range(n_nodes)]
        runq: List[List[int]] = [[] for _ in range(n_nodes)]
        busy = [False] * n_nodes
        widx = [0] * n_nodes
        active = [0] * n_nodes
        work_node = [0.0] * n_nodes
        busy_g = [0.0] * n_nodes
        cost = self._cost
        profiles = self._profiles
        decode_cache: Dict[Tuple[int, int], float] = {}

        # event sources: arrivals (sorted array), deadline events (sorted
        # once; event time max(deadline, arrival) as in submit()), and one
        # boundary heap entry per busy node
        arr_l = arrival.tolist()
        i_arr = 0
        didx = np.nonzero(np.isfinite(deadline))[0]
        devt = np.maximum(deadline, arrival)[didx]
        dorder = np.argsort(devt, kind="stable")
        d_ids = didx[dorder].tolist()
        d_ts = devt[dorder].tolist()
        n_dead = len(d_ids)
        d_ptr = 0
        bheap: List[Tuple[float, int, int]] = []
        seq = itertools.count()

        finished_order: List[int] = []
        fptr = 0
        flush_every = max(cfg.flush_every, 1)
        tenants = w.tenants
        tenant_id = np.ascontiguousarray(w.tenant_id, dtype=np.int64)
        t_sum = {t: StreamingSummary(slo_target=w.slo_targets.get(t))
                 for t in tenants}

        n_windows = 0
        n_coalesced = 0

        # -------------------------------------------------------------- #
        def flush(upto: int) -> None:
            nonlocal fptr
            ids = np.asarray(finished_order[fptr:upto], dtype=np.intp)
            fptr = upto
            if ids.size == 0:
                return
            tid = tenant_id[ids]
            arr = arrival[ids]
            jct = finish[ids] - arr
            ttft = first_tok[ids] - arr
            qd = qdelay[ids]
            pre = npre[ids]
            for ti, name in enumerate(tenants):
                m = tid == ti
                if m.any():
                    t_sum[name].add_batch(jct[m], qd[m], arr[m],
                                          ttft[m], pre[m])

        def expire(j: int, node: int, t: float) -> None:
            state[j] = EXPIRED
            finish[j] = t
            resident[j] = False
            swapped[j] = False
            pref[j] = 0
            active[node] -= 1
            work_node[node] -= workv[j]
            workv[j] = 0.0

        # mirror of LoadBalancer placement policies — min over the same
        # lexicographic keys, iterated in node-id order like the dicts
        if n_nodes == 1:
            def place(now: float, est: float) -> int:
                return 0
        elif placement == "least_jobs":
            def place(now: float, est: float) -> int:
                best, ba = 0, active[0]
                for m in range(1, n_nodes):
                    if active[m] < ba:
                        best, ba = m, active[m]
                return best
        elif placement == "least_predicted_work":
            def place(now: float, est: float) -> int:
                best, bw, ba = 0, work_node[0], active[0]
                for m in range(1, n_nodes):
                    wm = work_node[m]
                    if wm < bw or (wm == bw and active[m] < ba):
                        best, bw, ba = m, wm, active[m]
                return best
        else:  # least_eta
            def place(now: float, est: float) -> int:
                be = max(busy_g[0] - now, 0.0) + (work_node[0] + est) * cost[0]
                best, ba = 0, active[0]
                for m in range(1, n_nodes):
                    em = (max(busy_g[m] - now, 0.0)
                          + (work_node[m] + est) * cost[m])
                    if em < be or (em == be and active[m] < ba):
                        best, be, ba = m, em, active[m]
                return best

        # -------------------------------------------------------------- #
        while True:
            t_arr = arr_l[i_arr] if i_arr < n else INF
            t_d = d_ts[d_ptr] if d_ptr < n_dead else INF
            t_b = bheap[0][0] if bheap else INF
            if t_b is INF and t_arr is INF and t_d is INF:
                break

            # same-timestamp ordering as ELISFrontend._KIND_RANK:
            # arrival < deadline < node_free
            if t_arr <= t_d and t_arr <= t_b:
                now = t_arr
                j = i_arr
                i_arr += 1
                est = 0.0
                if track_work:
                    # ELISFrontend._arrival_estimate: one prediction per
                    # arrival (one RNG draw for the noisy oracle)
                    trem = float(length[j])
                    if noisy:
                        s = sigma_tab[0]
                        noise = rng.lognormal(-0.5 * s * s, s)
                        est = max(trem * noise * bias, 1.0)
                    else:
                        est = trem
                    est = max(est, 0.0)
                node = place(now, est)
                node_of[j] = node
                state[j] = WAITING
                last_enq[j] = now
                active[node] += 1
                work_node[node] += est
                workv[j] = est
                waitq[node].append(j)
                if not busy[node]:
                    heapq.heappush(bheap, (now, next(seq), node))
                    busy[node] = True
                continue

            if t_d <= t_b:
                now = t_d
                j = d_ids[d_ptr]
                d_ptr += 1
                st = state[j]
                if st == WAITING:
                    node = int(node_of[j])
                    waitq[node].remove(j)
                    expire(j, node, now)
                elif st == RUNNING:
                    # reachable when a window ends exactly on the deadline
                    node = int(node_of[j])
                    runq[node].remove(j)
                    expire(j, node, now)
                continue

            now, _, node = heapq.heappop(bheap)
            rq = runq[node]
            wq = waitq[node]
            if not rq and not wq:
                busy[node] = False
                continue

            # ---------------- scoring (score_pool mirror) -------------- #
            wi = widx[node]
            widx[node] = wi + 1
            full = (wi % stride == 0)
            nr = len(rq)
            pool = rq + wq
            idx = np.asarray(pool, dtype=np.intp)
            g = gen[idx]

            if policy == "fcfs":
                raw = arrival[idx]
            elif sjf:
                first = sjf_first[idx]
                need = np.isnan(first)
                if need.any():
                    sub = idx[need]
                    if noisy:
                        k = sub.size
                        s = sigma_tab[0]
                        noise = rng.lognormal(np.full(k, -0.5 * s * s),
                                              np.full(k, s))
                        f = np.maximum(
                            length[sub].astype(np.float64) * noise * bias,
                            1.0)
                    else:
                        f = length[sub].astype(np.float64)
                    sjf_first[sub] = f
                    first = sjf_first[idx]
                raw = np.maximum(first - g, 0.0)
            elif not noisy:  # oracle ISRTF: fresh == cached-decayed, always
                raw = (length[idx] - g).astype(np.float64)
            else:  # noisy ISRTF with the repredict stride
                if full:
                    steps = g // pred_step_window
                    s = sigma_tab[steps]
                    noise = rng.lognormal(mu_tab[steps], s)
                    raw = np.maximum(
                        (length[idx] - g).astype(np.float64) * noise * bias,
                        1.0)
                    prio_cache[idx] = raw
                    gen_at[idx] = g
                    scored[idx] = True
                else:
                    fresh = ~scored[idx]
                    raw = np.maximum(prio_cache[idx] - (g - gen_at[idx]), 0.0)
                    if fresh.any():
                        sub = idx[fresh]
                        gs = g[fresh]
                        steps = gs // pred_step_window
                        s = sigma_tab[steps]
                        noise = rng.lognormal(mu_tab[steps], s)
                        fr = np.maximum(
                            (length[sub] - gs).astype(np.float64)
                            * noise * bias, 1.0)
                        raw[fresh] = fr
                        prio_cache[sub] = fr
                        gen_at[sub] = gs
                        scored[sub] = True

            if chunked:
                # prefill debt joins the raw score exactly as score_pool's
                # ``p + prefill_debt(cfg, j)`` (then banding), so partially
                # prefilled / recompute-evicted jobs rank by TOTAL work
                debt = np.maximum(plen[idx] + g - pref[idx], 0
                                  ).astype(np.float64)
                eff = (raw + debt) + band[idx]
            else:
                eff = raw + band[idx]
            if aging > 0:
                le = last_enq[idx]
                m = ~np.isnan(le)
                if m.any():
                    eff[m] -= aging * np.maximum(now - le[m], 0.0)

            # predicted-work refresh (running then waiting, like
            # _form_batch): raw IS max(cached_expected_remaining, 0) for
            # every supported config, so refresh to it directly
            if refresh_work:
                cur = workv[idx]
                if not noisy:
                    # integer-valued: pairwise sum == sequential sum
                    work_node[node] += float(np.sum(raw - cur))
                else:
                    acc = work_node[node]
                    for a, b_ in zip(raw.tolist(), cur.tolist()):
                        acc += a - b_
                    work_node[node] = acc
                workv[idx] = raw

            # ---------------- preemption ------------------------------- #
            weff = eff[nr:]
            weff_l = weff.tolist()
            extra_swap = 0.0   # host<->device KV copy seconds this window
            if pcfg.enabled and nr and wq:
                run_pairs = list(zip(eff[:nr].tolist(), rq))
                nw = len(wq)
                if nw <= _VECTOR_CUTOVER:
                    wait_pairs = list(zip(weff_l, wq))
                else:
                    # only the best min(nr, nw) claimants can ever pair
                    top = np.lexsort((np.arange(nw), weff))[:min(nr, nw)]
                    wait_pairs = [(weff_l[k], wq[k]) for k in top.tolist()]
                swaps = select_preemptions(run_pairs, wait_pairs, pcfg)
                for vid, rid in swaps:
                    rq.remove(vid)
                    state[vid] = WAITING
                    npre[vid] += 1
                    last_enq[vid] = now
                    wq.append(vid)
                    vraw = raw[pool.index(vid)]
                    # swap-vs-recompute treatment of the victim's KV —
                    # same decide_preempt call / cost arithmetic as
                    # ELISFrontend + SimExecutor.preempt_costs
                    mode = "recompute"
                    if swap_policy:
                        n_kv = int(pref[vid])
                        costs = None
                        if n_kv > 0:
                            profv = profiles[node]
                            costs = (
                                2.0 * (swap_lat
                                       + n_kv * profv.kv_bytes_per_token
                                       / swap_bw),
                                profv.prefill_ms(1, n_kv) / 1000.0)
                        mode = decide_preempt(pcfg, costs, float(vraw))
                    if mode == "swap":
                        swapped[vid] = True
                        resident[vid] = False
                        extra_swap += (swap_lat
                                       + int(pref[vid])
                                       * profiles[node].kv_bytes_per_token
                                       / swap_bw)
                        n_swapouts += 1
                    else:
                        resident[vid] = False
                        pref[vid] = 0
                    # re-banded, zero-aging eff of the raw score this
                    # window used (frontend's cached_raw_priority patch,
                    # plus the post-evict/offload prefill debt)
                    if chunked:
                        debt_v = float(max(int(plen[vid]) + int(gen[vid])
                                           - int(pref[vid]), 0))
                        weff_l.append((float(vraw) + debt_v)
                                      + float(band[vid]))
                    else:
                        weff_l.append(float(vraw) + float(band[vid]))
                    k = wq.index(rid)
                    del wq[k]
                    del weff_l[k]
                    qdelay[rid] += max(now - last_enq[rid], 0.0)
                    last_enq[rid] = np.nan
                    state[rid] = RUNNING
                    rq.append(rid)

            # ---------------- fill (select_fills rule) ----------------- #
            free = cap - len(rq)
            if free > 0 and wq:
                if len(wq) <= _VECTOR_CUTOVER:
                    picks = select_fills(weff_l, free)
                else:
                    warr = np.asarray(weff_l)
                    picks = np.lexsort(
                        (np.arange(warr.size), warr))[:free].tolist()
                for jid in [wq[k] for k in picks]:
                    wq.remove(jid)
                    qdelay[jid] += max(now - last_enq[jid], 0.0)
                    last_enq[jid] = np.nan
                    state[jid] = RUNNING
                    rq.append(jid)

            # ---------------- execute (SimExecutor mirror) ------------- #
            batch = list(rq)
            b = len(batch)
            prof = profiles[node]
            dec = decode_cache.get((node, b))
            if dec is None:
                dec = prof.decode_ms(b)
                decode_cache[(node, b)] = dec
            prefill_ms = 0.0
            speedup = prof.prefill_speedup
            for jid in batch:
                if swapped[jid]:
                    # lazy swap-in on dispatch: copy time, KV + prefill
                    # cursor survive (SimExecutor.execute mirror)
                    swapped[jid] = False
                    resident[jid] = True
                    extra_swap += (swap_lat
                                   + int(pref[jid]) * prof.kv_bytes_per_token
                                   / swap_bw)
                    n_swapins += 1
                elif not resident[jid]:
                    nt = int(plen[jid] + gen[jid])
                    if gen[jid] > 0:
                        recompute_toks += nt
                    resident[jid] = True
                    if chunked:
                        pref[jid] = 0  # KV materialises chunk by chunk
                    else:
                        prefill_ms += nt * dec / speedup
                        pref[jid] = nt
            idxb = np.asarray(batch, dtype=np.intp)
            gb = gen[idxb]
            elig = None
            if chunked:
                # decode eligibility BEFORE the chunk advances: a job
                # completing its final chunk decodes from the next window
                goal = plen[idxb] + np.where(gb > 0, gb - 1, 0)
                elig = pref[idxb] >= goal
                if not bool(elig.all()):
                    # at most ONE batch-1 chunk per window, first
                    # incomplete job in batch order
                    k0 = int(np.nonzero(~elig)[0][0])
                    j0 = batch[k0]
                    n_c = min(chunk, int(goal[k0]) - int(pref[j0]))
                    dec1 = decode_cache.get((node, 1))
                    if dec1 is None:
                        dec1 = prof.decode_ms(1)
                        decode_cache[(node, 1)] = dec1
                    prefill_ms += n_c * dec1 / speedup
                    pref[j0] += n_c
            rem = length[idxb] - gb
            n_new = np.minimum(window, rem)
            if chunked:
                n_new = np.where(elig, n_new, 0)
                b_dec = int(elig.sum())
                if b_dec:
                    dec_e = decode_cache.get((node, b_dec))
                    if dec_e is None:
                        dec_e = prof.decode_ms(b_dec)
                        decode_cache[(node, b_dec)] = dec_e
                    decode_ms = int(n_new.max()) * dec_e
                else:
                    decode_ms = 0.0
            else:
                decode_ms = int(n_new.max()) * dec
            duration = overhead + (prefill_ms + decode_ms) / 1000.0
            if extra_swap:
                duration += extra_swap
            end = now + duration
            busy_g[node] = end

            # deadline-straddling windows: drop the tokens, expire at the
            # deadline (frontend's per-job check before applying tokens)
            if has_deadlines:
                dl = deadline[idxb]
                exm = dl < end
                if exm.any():
                    exm_l = exm.tolist()
                    dl_l = dl.tolist()
                    for k, jid in enumerate(batch):
                        if exm_l[k]:
                            rq.remove(jid)
                            expire(jid, node, dl_l[k])
                    keep = ~exm
                    batch = [jid for k, jid in enumerate(batch)
                             if not exm_l[k]]
                    idxb = idxb[keep]
                    gb = gb[keep]
                    rem = rem[keep]
                    n_new = n_new[keep]
                    if elig is not None:
                        elig = elig[keep]

            if batch:
                # Job.prefilled_tokens mirror: decoded jobs' KV now covers
                # prompt + everything generated (read before gen advances)
                if chunked:
                    pref[idxb] = np.where(elig, plen[idxb] + gb + n_new,
                                          pref[idxb])
                else:
                    pref[idxb] = plen[idxb] + gb + n_new
                gen[idxb] = gb + n_new
                niter[idxb] += 1
                ftb = first_tok[idxb]
                first_tok[idxb] = np.where(np.isnan(ftb) & (n_new > 0),
                                           end, ftb)
                fin = n_new >= rem
                fins: List[int] = []
                if track_work:
                    # sequential, interleaving decay-then-finish per job in
                    # batch order — the exact loop's accumulation order
                    # (mid-prefill jobs emit no tokens: no decay, exactly
                    # the frontend's ``if toks`` guard)
                    nn_l = n_new.tolist()
                    fin_l = fin.tolist()
                    acc = work_node[node]
                    for k, jid in enumerate(batch):
                        wv = workv[jid]
                        if nn_l[k] and wv > 0:
                            nv = max(wv - nn_l[k], 0.0)
                            acc += nv - wv
                            workv[jid] = nv
                        if fin_l[k]:
                            acc -= workv[jid]
                            workv[jid] = 0.0
                            fins.append(jid)
                    work_node[node] = acc
                else:
                    fins = [jid for jid, f in zip(batch, fin.tolist()) if f]
                for jid in fins:
                    state[jid] = FINISHED
                    finish[jid] = end
                    rq.remove(jid)
                    active[node] -= 1
                    resident[jid] = False
                    pref[jid] = 0
                    finished_order.append(jid)
            n_windows += 1

            # ---------------- window coalescing ------------------------ #
            if coalesce and rq and not wq:
                idx2 = np.asarray(rq, dtype=np.intp)
                if not has_deadlines or \
                        not np.isfinite(deadline[idx2]).any():
                    rem2 = length[idx2] - gen[idx2]
                    k1 = (int(rem2.min()) - 1) // window
                    if k1 > 0:
                        t_next = arr_l[i_arr] if i_arr < n else INF
                        b2 = len(rq)
                        dec2 = decode_cache.get((node, b2))
                        if dec2 is None:
                            dec2 = profiles[node].decode_ms(b2)
                            decode_cache[(node, b2)] = dec2
                        dur_full = overhead + (window * dec2) / 1000.0
                        k = 0
                        while k < k1 and t_next > end:
                            # bit-exact clock: same sequential accumulation
                            # as k separate windows
                            end = end + dur_full
                            k += 1
                        if k:
                            gen[idx2] += k * window
                            niter[idx2] += k
                            widx[node] += k
                            n_windows += k
                            n_coalesced += k
                            busy_g[node] = end
                            if track_work:
                                total = k * window
                                acc = work_node[node]
                                for jid in rq:
                                    wv = workv[jid]
                                    if wv > 0:
                                        nv = max(wv - total, 0.0)
                                        acc += nv - wv
                                        workv[jid] = nv
                                work_node[node] = acc

            heapq.heappush(bheap, (end, next(seq), node))
            if len(finished_order) - fptr >= flush_every:
                flush(len(finished_order))

        flush(len(finished_order))
        return ScaleResult(
            cfg=cfg, workload=w, state=state, finish=finish,
            first_token=first_tok, queuing_delay=qdelay,
            n_preemptions=npre, n_iterations=niter,
            finished_order=np.asarray(finished_order, dtype=np.int64),
            tenant_summaries=t_sum, n_windows=n_windows,
            n_coalesced=n_coalesced, wall_s=time.perf_counter() - t0,
            n_swapouts=n_swapouts, n_swapins=n_swapins,
            recompute_prefill_tokens=recompute_toks)


# --------------------------------------------------------------------------- #
# Exact reference (validation slices)
# --------------------------------------------------------------------------- #


@dataclass
class ExactResult:
    """The exact event loop's outcome, shaped like :class:`ScaleResult`
    for elementwise comparison."""

    state: np.ndarray
    finish: np.ndarray
    first_token: np.ndarray
    queuing_delay: np.ndarray
    n_preemptions: np.ndarray
    n_iterations: np.ndarray
    finished_order: np.ndarray
    jobs: list
    # executor-side swap/recompute totals, compared against ScaleResult's
    n_swapouts: int = 0
    n_swapins: int = 0
    recompute_prefill_tokens: int = 0


def run_exact_reference(cfg: ScaleSimConfig, w: ScaleWorkload) -> ExactResult:
    """Drive :class:`ELISFrontend` + :class:`SimExecutor` over the same
    workload/config — the ground truth the fast path is validated against."""
    from repro.core.frontend import ELISFrontend, FrontendConfig
    from repro.core.job import Job, JobState
    from repro.core.predictor import make_predictor
    from repro.core.scheduler import SchedulerConfig
    from repro.simulate.executor import SimExecutor

    cfg.validate()
    profs = cfg.profiles()
    base = _resolve_profile(cfg.model).scaled(cfg.hw_speedup)
    node_profiles = None
    if cfg.node_profiles:
        node_profiles = {n: _resolve_profile(name).scaled(cfg.hw_speedup)
                         for n, name in cfg.node_profiles.items()}
    kw = ({} if cfg.sched_overhead_s is None
          else {"sched_overhead_s": cfg.sched_overhead_s})
    executor = SimExecutor(profile=base, node_profiles=node_profiles,
                           swap_bandwidth_bytes_s=cfg.swap_bandwidth_bytes_s,
                           swap_latency_s=cfg.swap_latency_s, **kw)
    predictor = make_predictor(cfg.predictor, seed=cfg.seed + 1,
                               bias=cfg.predictor_bias)
    fcfg = FrontendConfig(
        n_nodes=cfg.n_nodes,
        scheduler=SchedulerConfig(
            policy=cfg.policy, window=cfg.window, batch_size=cfg.batch_size,
            aging_rate=cfg.aging_rate, repredict_every=cfg.repredict_every,
            prefill_chunk=cfg.prefill_chunk, rank_by=cfg.rank_by),
        preemption=cfg.preemption,
        placement=cfg.placement,
        node_token_cost=executor.node_token_cost(cfg.n_nodes),
    )
    fe = ELISFrontend(fcfg, predictor, executor)
    tok = 5
    jobs = []
    for i in range(w.n):
        L = int(w.length[i])
        dl = float(w.deadline[i])
        job = Job(
            job_id=i, prompt=f"scale request {i}",
            prompt_tokens=[tok] * int(w.prompt_len[i]),
            arrival_time=float(w.arrival[i]),
            true_output_len=L, output_tokens=[tok] * L,
            deadline=None if math.isinf(dl) else dl,
            tenant=w.tenants[int(w.tenant_id[i])],
            priority_class=int(w.priority_class[i]),
        )
        jobs.append(job)
        fe.submit(job)
    fe.run()

    n = w.n
    state = np.zeros(n, dtype=np.int8)
    finish = np.full(n, np.nan)
    first_token = np.full(n, np.nan)
    qd = np.zeros(n)
    pre = np.zeros(n, dtype=np.int64)
    it = np.zeros(n, dtype=np.int64)
    code = {JobState.WAITING: WAITING, JobState.RUNNING: RUNNING,
            JobState.PREEMPTED: WAITING, JobState.FINISHED: FINISHED,
            JobState.EXPIRED: EXPIRED}
    for job in jobs:
        state[job.job_id] = code.get(job.state, UNARRIVED)
        if job.finish_time is not None:
            finish[job.job_id] = job.finish_time
        if job.first_token_time is not None:
            first_token[job.job_id] = job.first_token_time
        qd[job.job_id] = job.queuing_delay
        pre[job.job_id] = job.n_preemptions
        it[job.job_id] = job.n_iterations
    order = np.asarray([j.job_id for j in fe.finished], dtype=np.int64)
    assert len(profs) == cfg.n_nodes
    return ExactResult(state=state, finish=finish, first_token=first_token,
                       queuing_delay=qd, n_preemptions=pre, n_iterations=it,
                       finished_order=order, jobs=jobs,
                       n_swapouts=executor.n_swapouts,
                       n_swapins=executor.n_swapins,
                       recompute_prefill_tokens=
                       executor.recompute_prefill_tokens)
