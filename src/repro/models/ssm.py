"""Mamba2 (State Space Duality) block — pure JAX reference implementation.

TPU adaptation: the CUDA selective-scan of Mamba1 is replaced by Mamba2's SSD
*chunked* formulation (arXiv:2405.21060 §6): within a chunk the recurrence is
computed as dense attention-like matmuls (MXU-friendly), and chunks are linked
by a tiny sequential state carry (``lax.scan`` over n_chunks).  The Pallas
kernel in ``repro/kernels/ssm_scan.py`` blocks the same computation into VMEM
tiles; this module is the oracle.

Block structure (Mamba2):
    u -> in_proj -> [z | x | B | C | dt]
    (x,B,C) -> causal depthwise conv1d -> silu
    y = SSD(x * dt, dt * A, B, C) + D * x
    out = out_proj( RMSNorm(y) * silu(z) )    # gated norm

State for decode:
    conv_state: (B, conv_ch, d_conv - 1)   last raw conv inputs
    ssm_state:  (B, n_heads, head_dim, d_state)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, jnp.ndarray]


def conv_channels(cfg) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state


def init_ssm(key, cfg, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = cfg.ssm_d_inner
    nh = cfg.ssm_n_heads
    g = s.n_groups
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * g * s.d_state + nh  # z, x, B, C, dt
    lo, hi = s.a_init_range
    a = jax.random.uniform(ks[2], (nh,), jnp.float32, lo, hi)
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": jax.random.normal(ks[1], (conv_channels(cfg), s.d_conv), dtype)
        * 0.1,
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "A_log": jnp.log(a),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


# --------------------------------------------------------------------------- #
# SSD chunked scan (reference)
# --------------------------------------------------------------------------- #


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., c) -> (..., c, c) with out[t, s] = sum_{s < r <= t} a[r]
    (lower-triangular; -inf above diagonal)."""
    c = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)  already multiplied by dt
    a: jnp.ndarray,  # (B, S, H)     log decay per step = dt * A  (negative)
    Bm: jnp.ndarray,  # (B, S, H, N)
    Cm: jnp.ndarray,  # (B, S, H, N)
    chunk: int,
    initial_state: jnp.ndarray = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def tochunk(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, ac, bc, cc = map(tochunk, (x, a, Bm, Cm))
    ac = jnp.moveaxis(ac, -1, 2)  # (B, nc, H, c)
    a_cum = jnp.cumsum(ac, axis=-1)  # (B, nc, H, c)

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(ac))  # (B, nc, H, c, c)
    y_diag = jnp.einsum("bzthn,bzshn,bzhts,bzshp->bzthp", cc, bc, L, xc)

    # states at the end of each chunk
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B, nc, H, c)
    states = jnp.einsum("bzshn,bzhs,bzshp->bzhpn", bc, decay_states, xc)

    # inter-chunk carry, fully vectorised (TPU-friendly: one (nc+1)² decay
    # matrix instead of a sequential scan — also keeps XLA cost analysis
    # exact, since while-loop bodies are otherwise counted only once)
    chunk_log_decay = a_cum[..., -1]  # (B, nc, H)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)
    # stack initial state as the "chunk -1" contribution with log-decay 0
    cum = jnp.cumsum(chunk_log_decay, axis=1)  # (B, nc, H)
    cum0 = jnp.pad(cum, ((0, 0), (1, 0), (0, 0)))  # (B, nc+1, H): cum before z
    # M[z, w] = exp(cum0[z] - cum0[w+1]) for w < z : decay applied to chunk
    # w's end-state when it reaches the start of chunk z
    expo = cum0[:, :, None, :] - cum0[:, None, 1:, :]  # (B, nc+1(z), nc(w), H)
    zi = jnp.arange(nc + 1)[:, None]
    wi = jnp.arange(nc)[None, :]
    valid = wi < zi  # strict: chunk w finished before chunk z starts
    M = jnp.where(valid[None, :, :, None], jnp.exp(
        jnp.where(valid[None, :, :, None], expo, 0.0)), 0.0)
    all_prev = jnp.einsum("bzwh,bwhpn->bzhpn", M.astype(states.dtype), states)
    # initial-state contribution decays through every prior chunk
    init_decay = jnp.exp(cum0)  # (B, nc+1, H)
    all_prev = all_prev + init_decay[..., None, None].astype(
        states.dtype) * initial_state[:, None]
    prev_states = all_prev[:, :nc]  # state at the START of each chunk
    final_state = all_prev[:, nc]

    # inter-chunk (off-diagonal) contribution
    state_decay_out = jnp.exp(a_cum)  # (B, nc, H, c)
    y_off = jnp.einsum("bzthn,bzhpn,bzht->bzthp", cc, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


# --------------------------------------------------------------------------- #
# Block forward / decode
# --------------------------------------------------------------------------- #


def _split_proj(cfg, proj: jnp.ndarray):
    di = cfg.ssm_d_inner
    g = cfg.ssm.n_groups
    n = cfg.ssm.d_state
    nh = cfg.ssm_n_heads
    z, xin, bm, cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    assert dt.shape[-1] == nh
    return z, xin, bm, cm, dt


def _causal_conv(p: Params, seq: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, CH)."""
    w = p["conv_w"]  # (CH, K)
    k = w.shape[-1]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    # windowed dot, k is small (4) so unroll: out[t] = sum_j w[j] * x[t+j-(k-1)]
    out = sum(
        pad[:, j : j + seq.shape[1], :] * w[:, j][None, None, :] for j in range(k)
    )
    return out + p["conv_b"][None, None, :]


def _heads(cfg, xin, bm, cm):
    b, s, _ = xin.shape
    nh, hd = cfg.ssm_n_heads, cfg.ssm.head_dim
    g, n = cfg.ssm.n_groups, cfg.ssm.d_state
    xh = xin.reshape(b, s, nh, hd)
    bmh = bm.reshape(b, s, g, n)
    cmh = cm.reshape(b, s, g, n)
    rep = nh // g
    bmh = jnp.repeat(bmh, rep, axis=2)
    cmh = jnp.repeat(cmh, rep, axis=2)
    return xh, bmh, cmh


def _gated_out(p: Params, cfg, y: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + 1e-5) * p["norm"].astype(jnp.float32)
    out = (yn * jax.nn.silu(z.astype(jnp.float32))).astype(y.dtype)
    return out @ p["out_proj"]


def ssm_forward(p: Params, cfg, x: jnp.ndarray, *, impl: str = "xla",
                return_state: bool = False):
    """Full-sequence Mamba2 block. x (B, S, d_model) -> (B, S, d_model).

    With ``return_state`` the second return value is the full decode state
    ({"conv", "ssm"}) so prefill can hand off to ``ssm_decode_step`` exactly.
    """
    b, s, _ = x.shape
    proj = x @ p["in_proj"]
    z, xin, bm, cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)
    tail = cfg.ssm.d_conv - 1
    if s >= tail:
        conv_tail = jnp.moveaxis(conv_in[:, s - tail :, :], 1, 2)
    else:
        conv_tail = jnp.pad(
            jnp.moveaxis(conv_in, 1, 2), ((0, 0), (0, 0), (tail - s, 0))
        )
    conv_out = jax.nn.silu(_causal_conv(p, conv_in))
    di = cfg.ssm_d_inner
    gn = cfg.ssm.n_groups * cfg.ssm.d_state
    xin, bm, cm = jnp.split(conv_out, [di, di + gn], axis=-1)
    xh, bmh, cmh = _heads(cfg, xin, bm, cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    a_log = dt * A[None, None, :]
    x_dt = xh * dt[..., None].astype(xh.dtype)

    chunk = min(cfg.ssm.chunk_size, s)
    # pad sequence to a multiple of chunk
    pad = (-s) % chunk
    if pad:
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        bmh = jnp.pad(bmh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmh = jnp.pad(cmh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if impl == "pallas":
        from repro.kernels import ops as kops

        y, final_state = kops.ssd_scan(x_dt, a_log.astype(jnp.float32), bmh,
                                       cmh, chunk=chunk)
    else:
        y, final_state = ssd_chunked(x_dt, a_log.astype(x_dt.dtype), bmh, cmh,
                                     chunk)
    if pad:
        y = y[:, :s]
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, s, di)
    out = _gated_out(p, cfg, y, z)
    if return_state:
        return out, {"conv": conv_tail, "ssm": final_state}
    return out, final_state


def init_ssm_state(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, conv_channels(cfg), cfg.ssm.d_conv - 1), dtype),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_n_heads, cfg.ssm.head_dim, cfg.ssm.d_state), dtype
        ),
    }


def ssm_decode_step(p: Params, cfg, x: jnp.ndarray, state: Dict):
    """Single-token recurrent step.  x (B, 1, d_model)."""
    b = x.shape[0]
    proj = x[:, 0, :] @ p["in_proj"]  # (B, proj)
    z, xin, bm, cm, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)  # (B, CH)
    conv_hist = jnp.concatenate(
        [state["conv"], conv_in[:, :, None]], axis=-1
    )  # (B, CH, d_conv)
    w = p["conv_w"]  # (CH, K)
    conv_out = jnp.einsum("bck,ck->bc", conv_hist, w) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = conv_hist[:, :, 1:]

    di = cfg.ssm_d_inner
    gn = cfg.ssm.n_groups * cfg.ssm.d_state
    xin, bm, cm = jnp.split(conv_out, [di, di + gn], axis=-1)
    nh, hd = cfg.ssm_n_heads, cfg.ssm.head_dim
    g, n = cfg.ssm.n_groups, cfg.ssm.d_state
    xh = xin.reshape(b, nh, hd)
    bmh = jnp.repeat(bm.reshape(b, g, n), nh // g, axis=1)
    cmh = jnp.repeat(cm.reshape(b, g, n), nh // g, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A[None, :])  # (B, H)

    h = state["ssm"]
    h = h * da[:, :, None, None].astype(h.dtype) + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, bmh, dt.astype(xh.dtype)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, cmh)
    y = y + xh * p["D"][None, :, None].astype(xh.dtype)
    y = y.reshape(b, 1, di)
    out = _gated_out(p, cfg, y, z[:, None, :])
    return out, {"conv": new_conv_state, "ssm": h}


def ssd_reference_sequential(x, a, Bm, Cm, initial_state=None):
    """O(S) sequential recurrence — ground truth for tests.

    x (B,S,H,P) pre-multiplied by dt; a (B,S,H) log decay; Bm/Cm (B,S,H,N).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)

    def step(hprev, inp):
        xt, at, bt, ct = inp
        hnew = hprev * jnp.exp(at)[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt, bt
        )
        yt = jnp.einsum("bhpn,bhn->bhp", hnew, ct)
        return hnew, yt

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    final, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1), final
