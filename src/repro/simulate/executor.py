"""Discrete-event cluster executor.

Implements the frontend's ``Backend`` ABC with virtual time and the
calibrated latency model.  Replays each job's pre-generated response token
stream (the simulator never invents tokens — ground truth lives with the
workload generator), tracks per-node KV residency for preemption/recompute
accounting, and enforces the Appendix-A memory capacity.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.frontend import Backend, ExecResult
from repro.core.job import Job
from repro.simulate.profiles import SCHED_OVERHEAD_MS, ModelProfile


@dataclass
class SimExecutor(Backend):
    profile: ModelProfile
    #: include the paper's measured 11.04 ms scheduling overhead per iteration
    sched_overhead_s: float = SCHED_OVERHEAD_MS / 1000.0
    #: cap on resident KV tokens per node (None = Appendix-A capacity)
    kv_capacity_tokens: int = None

    _resident: Dict[int, Set[int]] = field(default_factory=dict)
    _resident_tokens: Dict[int, Dict[int, int]] = field(default_factory=dict)
    mem_preemptions: int = 0

    def __post_init__(self):
        if self.kv_capacity_tokens is None:
            self.kv_capacity_tokens = self.profile.kv_capacity_tokens()

    # ------------------------------------------------------------------ #
    def evict(self, node: int, job: Job) -> None:
        self._resident.setdefault(node, set()).discard(job.job_id)
        self._resident_tokens.setdefault(node, {}).pop(job.job_id, None)

    def resident_token_count(self, node: int) -> int:
        return sum(self._resident_tokens.get(node, {}).values())

    def capacity(self, node: int) -> Optional[int]:
        # job count is unbounded in the simulator; residency is bounded by
        # KV *tokens* (Appendix-A memory model), enforced inside execute()
        return None

    def free_capacity(self, node: int) -> Optional[int]:
        return None

    # ------------------------------------------------------------------ #
    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float) -> ExecResult:
        res = self._resident.setdefault(node, set())
        res_toks = self._resident_tokens.setdefault(node, {})
        b = len(jobs)

        prefill_ms = 0.0
        for job in jobs:
            if job.job_id not in res:
                # cold start or resumed-after-preemption: recompute the KV
                # cache for everything generated so far (vLLM recompute mode)
                n = len(job.prompt_tokens) + job.tokens_generated
                prefill_ms += self.profile.prefill_ms(b, n)
                res.add(job.job_id)
                res_toks[job.job_id] = n

        tokens_out: List[List[int]] = []
        finished: List[bool] = []
        max_new = 0
        for job in jobs:
            remaining = job.true_output_len - job.tokens_generated
            n_new = min(window, remaining)
            start = job.tokens_generated
            tokens_out.append(job.output_tokens[start : start + n_new])
            finished.append(n_new >= remaining)
            res_toks[job.job_id] = res_toks.get(job.job_id, 0) + n_new
            max_new = max(max_new, n_new)

        decode_ms = max_new * self.profile.decode_ms(b)
        duration = self.sched_overhead_s + (prefill_ms + decode_ms) / 1000.0

        # Appendix-A memory pressure: if resident KV exceeds capacity, evict
        # the largest non-batch residents (counted as memory preemptions)
        total = sum(res_toks.values())
        if total > self.kv_capacity_tokens:
            batch_ids = {j.job_id for j in jobs}
            evictable = sorted(
                ((t, jid) for jid, t in res_toks.items()
                 if jid not in batch_ids),
                reverse=True,
            )
            for t, jid in evictable:
                if total <= self.kv_capacity_tokens:
                    break
                res.discard(jid)
                res_toks.pop(jid)
                total -= t
                self.mem_preemptions += 1

        return ExecResult(duration, tokens_out, finished)
