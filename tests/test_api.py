"""Online serving API: request lifecycle, streaming, cancellation, deadlines,
and steppable-frontend equivalence with the legacy drain-once path."""
from typing import Sequence

import pytest

from repro.core import (
    ELISFrontend,
    ElisServer,
    ExecResult,
    FrontendConfig,
    Job,
    OraclePredictor,
    PreemptionConfig,
    Request,
    RequestOptions,
    RequestStatus,
    SchedulerConfig,
)
from repro.core.frontend import Backend


class RecordingBackend(Backend):
    """Deterministic backend: every window takes 1s, emits token id 7.
    Tracks per-node residency so tests can assert slots are freed."""

    def __init__(self, slots: int = 4):
        self.slots = slots
        self.resident = {}  # node -> set(job_id)
        self.calls = []
        self.evictions = []

    def execute(self, node, jobs: Sequence[Job], window, now) -> ExecResult:
        res = self.resident.setdefault(node, set())
        self.calls.append((now, node, [j.job_id for j in jobs]))
        toks, fin = [], []
        for j in jobs:
            res.add(j.job_id)
            n = min(window, j.true_output_len - j.tokens_generated)
            toks.append([7] * n)
            fin.append(j.tokens_generated + n >= j.true_output_len)
        return ExecResult(1.0, toks, fin)

    def evict(self, node, job):
        self.evictions.append(job.job_id)
        self.resident.setdefault(node, set()).discard(job.job_id)

    def capacity(self, node):
        return self.slots

    def free_capacity(self, node):
        return self.slots - len(self.resident.get(node, ()))


def make_server(policy="fcfs", batch=2, window=50, preempt=False,
                slots=4, n_nodes=1):
    backend = RecordingBackend(slots=slots)
    server = ElisServer(
        FrontendConfig(
            n_nodes=n_nodes,
            scheduler=SchedulerConfig(policy=policy, window=window,
                                      batch_size=batch),
            preemption=PreemptionConfig(enabled=preempt, margin=10,
                                        max_fraction=1.0),
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        backend,
    )
    return server, backend


def req(i, length, arrival=0.0, **opts):
    return Request(prompt=f"p{i}", prompt_tokens=[1, 2], arrival_time=arrival,
                   request_id=i, true_output_len=length,
                   options=RequestOptions(**opts))


# --------------------------------------------------------------------------- #
# basic lifecycle
# --------------------------------------------------------------------------- #


def test_submit_returns_handle_and_response_is_not_a_job():
    server, _ = make_server()
    h = server.submit(req(0, 75))
    assert h.status is RequestStatus.QUEUED and not h.done
    [r] = server.drain()
    assert h.status is RequestStatus.FINISHED and h.done
    assert r.status is RequestStatus.FINISHED and r.ok
    assert not isinstance(r, Job) and not isinstance(h, Job)
    assert r.n_tokens == 75
    assert r.n_iterations == 2          # 50 + 25
    assert r.jct() == pytest.approx(2.0)
    assert h.result() == r


def test_duplicate_request_id_rejected():
    server, _ = make_server()
    server.submit(req(5, 10))
    with pytest.raises(ValueError):
        server.submit(req(5, 10))


def test_max_tokens_caps_generation():
    server, _ = make_server()
    h = server.submit(req(0, 120, max_tokens=60))
    server.drain()
    assert h.result().n_tokens == 60


# --------------------------------------------------------------------------- #
# streaming
# --------------------------------------------------------------------------- #


def test_streaming_chunks_arrive_in_generation_order():
    server, _ = make_server(batch=2)
    h0 = server.submit(req(0, 120, stream=True))
    server.submit(req(1, 80))
    chunks = list(server.stream(h0))
    assert chunks, "stream produced no chunks"
    assert [c.index for c in chunks] == sorted(c.index for c in chunks)
    assert all(c.request_id == 0 for c in chunks)
    # chunk times never go backwards
    assert all(a.t <= b.t for a, b in zip(chunks, chunks[1:]))
    # exactly one final chunk, and it is the last one
    assert [c.final for c in chunks].count(True) == 1 and chunks[-1].final
    # concatenation equals the terminal response stream
    server.drain()
    flat = [t for c in chunks for t in c.tokens]
    assert tuple(flat) == h0.result().tokens
    assert len(flat) == 120


def test_stream_of_finished_request_replays_chunks():
    server, _ = make_server()
    h = server.submit(req(0, 75, stream=True))
    server.drain()
    chunks = list(server.stream(h))
    assert len(chunks) == 2 and chunks[-1].final


def test_stream_requires_stream_option():
    server, _ = make_server()
    h = server.submit(req(0, 75))          # stream not requested
    server.drain()
    with pytest.raises(ValueError):
        next(server.stream(h))
    # non-streaming requests retain no chunks (bounded memory)
    assert server.frontend.jobs[0].chunks == []


def test_release_drops_terminal_request_records():
    server, _ = make_server()
    h = server.submit(req(0, 75))
    assert not server.release(h)           # still live
    server.drain()
    assert server.release(h)
    assert server.frontend.jobs == {} and server.frontend.finished == []
    with pytest.raises(KeyError):
        server.status(h)
    assert not server.release(h)           # already released


# --------------------------------------------------------------------------- #
# cancellation
# --------------------------------------------------------------------------- #


def test_cancel_waiting_job_frees_load_and_never_finishes():
    server, backend = make_server(batch=1)
    server.submit(req(0, 100))
    h1 = server.submit(req(1, 100))
    server.step()          # arrival 0
    server.step()          # arrival 1 (queued behind 0, batch=1)
    assert server.cancel(h1)
    assert h1.status is RequestStatus.CANCELLED
    responses = server.drain()
    assert {r.request_id: r.status for r in responses} == {
        0: RequestStatus.FINISHED, 1: RequestStatus.CANCELLED}
    # the cancelled job never executed and holds no backend residency
    assert all(1 not in ids for _, _, ids in backend.calls)
    assert 1 not in backend.resident.get(0, ())
    # load-balancer count released
    assert server.frontend.state.active_jobs[0] == 0
    # cancel of a terminal request is a no-op
    assert not server.cancel(h1)


def test_cancel_running_job_evicts_and_frees_slot():
    server, backend = make_server(batch=1, window=10)
    h = server.submit(req(0, 100))
    # step until the first window has executed
    while not backend.calls:
        server.step()
    assert h.status is RequestStatus.RUNNING
    assert server.cancel(h)
    server.drain()
    assert h.status is RequestStatus.CANCELLED
    r = h.result()
    assert r.status is RequestStatus.CANCELLED and not r.ok
    assert 0 < r.n_tokens < 100          # partial output retained
    assert 0 in backend.evictions        # slot released through the backend
    assert backend.resident.get(0, set()) == set()
    assert backend.free_capacity(0) == backend.slots
    # a cancelled job is terminal CANCELLED, never FINISHED
    assert all(j.job_id != 0 for j in server.frontend.finished)


def test_cancel_before_arrival():
    server, backend = make_server()
    h = server.submit(req(0, 50, arrival=5.0))
    assert server.cancel(h)
    server.drain()
    assert h.status is RequestStatus.CANCELLED
    assert backend.calls == []


# --------------------------------------------------------------------------- #
# deadlines
# --------------------------------------------------------------------------- #


def test_deadline_expiry_marks_expired_and_frees_slot():
    server, backend = make_server(batch=1, window=10)
    # 1s per 10-token window -> needs 10s; deadline at 3.5s
    h = server.submit(req(0, 100, deadline=3.5))
    server.drain()
    assert h.status is RequestStatus.EXPIRED
    r = h.result()
    assert r.status is RequestStatus.EXPIRED
    assert r.finish_time == pytest.approx(3.5)
    assert 0 < r.n_tokens < 100
    assert 0 in backend.evictions
    assert backend.resident.get(0, set()) == set()


def test_deadline_expiry_while_queued():
    server, _ = make_server(batch=1)
    server.submit(req(0, 500))                      # hogs the only slot
    h = server.submit(req(1, 50, deadline=2.0))     # expires in the queue
    server.drain()
    assert h.status is RequestStatus.EXPIRED
    assert h.result().n_tokens == 0


def test_deadline_inside_final_window_expires_not_finishes():
    """Regression: a job whose deadline fell inside its last executing
    window used to FINISH with finish_time > deadline (results were applied
    before the pending deadline event fired).  Expiry is now enforced at
    the window boundary: the straddling window's tokens are dropped and the
    job surfaces as EXPIRED at the deadline."""
    server, backend = make_server(batch=1)
    # 1 s per 50-token window: 100 tokens finish at t=2.0; deadline 1.5
    # falls inside the second window
    h = server.submit(req(0, 100, deadline=1.5))
    server.drain()
    r = h.result()
    assert r.status is RequestStatus.EXPIRED and not r.ok
    assert r.finish_time == pytest.approx(1.5)
    assert r.n_tokens == 50              # second window's tokens dropped
    assert 0 in backend.evictions
    assert backend.resident.get(0, set()) == set()
    assert all(j.job_id != 0 for j in server.frontend.finished)


def test_deadline_after_finish_is_harmless():
    server, _ = make_server()
    h = server.submit(req(0, 40, deadline=100.0))
    [r] = server.drain()
    assert r.status is RequestStatus.FINISHED
    assert h.status is RequestStatus.FINISHED


# --------------------------------------------------------------------------- #
# steppable frontend: step / run_until / late submit
# --------------------------------------------------------------------------- #


def _legacy_jcts(lens, arrivals, *, policy="fcfs", batch=2):
    """Drain-once reference on the legacy Job-level frontend."""
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=1,
            scheduler=SchedulerConfig(policy=policy, window=50,
                                      batch_size=batch),
            preemption=PreemptionConfig(enabled=policy != "fcfs", margin=10,
                                        max_fraction=1.0),
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        RecordingBackend(),
    )
    for i, (l, a) in enumerate(zip(lens, arrivals)):
        fe.submit(Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1, 2],
                      arrival_time=a, true_output_len=l))
    return {j.job_id: j.jct() for j in fe.run()}


@pytest.mark.parametrize("policy", ["fcfs", "isrtf"])
def test_interleaved_step_run_until_matches_single_run(policy):
    lens = [120, 60, 200, 50, 90]
    arrivals = [0.0, 0.5, 1.0, 4.0, 6.5]
    want = _legacy_jcts(lens, arrivals, policy=policy)

    server, _ = make_server(policy=policy, batch=2,
                            preempt=(policy != "fcfs"))
    # late submission: requests enter the open loop as time advances,
    # always before their arrival times are reached
    server.submit(req(0, lens[0], arrival=arrivals[0]))
    server.submit(req(1, lens[1], arrival=arrivals[1]))
    server.run_until(0.75)
    server.submit(req(2, lens[2], arrival=arrivals[2]))
    server.run_until(3.0)
    server.submit(req(3, lens[3], arrival=arrivals[3]))
    for _ in range(3):
        server.step(5.0)       # bounded stepping, then a late submit
    server.submit(req(4, lens[4], arrival=arrivals[4]))
    responses = server.drain()

    got = {r.request_id: r.jct() for r in responses}
    assert got == pytest.approx(want)


def test_interleaved_with_cancel_matches_legacy_without_the_cancelled_job():
    # FCFS batch=1: job 3 arrives last and is cancelled while queued, so the
    # remaining jobs' schedule must match a legacy run that never saw job 3
    lens, arrivals = [100, 60, 80, 50], [0.0, 0.1, 0.2, 0.3]
    want = _legacy_jcts(lens[:3], arrivals[:3], batch=1)

    server, _ = make_server(batch=1)
    handles = [server.submit(req(i, l, arrival=a))
               for i, (l, a) in enumerate(zip(lens, arrivals))]
    server.run_until(1.0)                 # all arrived; 3 still queued
    assert server.cancel(handles[3])
    responses = server.drain()
    got = {r.request_id: r.jct() for r in responses if r.ok}
    assert got == pytest.approx(want)
    assert handles[3].status is RequestStatus.CANCELLED


def test_step_respects_now_and_clock_advances():
    server, backend = make_server()
    server.submit(req(0, 50, arrival=2.0))
    assert server.step(1.0) == []         # arrival not due yet
    assert server.now == 1.0
    assert backend.calls == []
    server.run_until(2.0)                 # arrival + dispatch due
    assert backend.calls and server.now == 2.0


def test_late_submit_before_past_arrival_is_clamped():
    server, _ = make_server()
    server.run_until(10.0)
    h = server.submit(req(0, 50, arrival=1.0))    # arrival in the past
    [r] = server.drain()
    assert r.ok
    # admitted at the current clock, not retroactively
    assert r.finish_time >= 10.0


def test_priority_class_outranks_predicted_length():
    # isrtf, batch=1: the long class-0 job beats the short class-1 job
    server, _ = make_server(policy="isrtf", batch=1)
    h_long = server.submit(req(0, 150, priority_class=0))
    h_short = server.submit(req(1, 50, priority_class=1))
    server.drain()
    assert h_long.result().finish_time < h_short.result().finish_time


def test_events_surface_lifecycle_transitions():
    server, _ = make_server(batch=1, window=50)
    h = server.submit(req(0, 75, deadline=50.0))
    kinds = []
    while server.pending():
        kinds.extend(e.kind for e in server.step())
    assert kinds[0] == "arrival"
    assert "tokens" in kinds and "finished" in kinds
    assert "expired" not in kinds
    assert h.status is RequestStatus.FINISHED
