"""Logical-axis → PartitionSpec rules (t5x-style, path-pattern driven).

Baseline sharding scheme (hillclimbed in EXPERIMENTS.md §Perf):
  * batch            → ("pod", "data") (or ("data",) single-pod)
  * vocab / heads / ffn-hidden / ssm-inner → "model"  (tensor parallelism,
    including *within each expert* for MoE — experts replicated; the
    expert-parallel alternative is a §Perf experiment)
  * long-context decode (global_batch < data axis): KV-cache sequence axis
    → "data" (context parallelism), batch replicated
  * layer-stack axes, norms, embed width → replicated

Specs are derived structurally: every param/cache leaf is matched by the
name path produced by the same init functions, so new modules fail loudly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"#{e.idx}")
        elif hasattr(e, "name"):
            names.append(str(e.name))
        else:
            names.append(str(e))
    return tuple(names)


#: leaf-name -> (logical ndim, spec tail) — leading stacked axes padded None
_PARAM_RULES: Dict[str, Tuple[int, Tuple]] = {
    "embed": (2, ("model", None)),        # (vocab, d)
    "lm_head": (2, (None, "model")),      # (d, vocab)
    "pos_embed": (2, (None, None)),
    "enc_pos": (2, (None, None)),
    "wq": (2, (None, "model")),
    "wk": (2, (None, "model")),
    "wv": (2, (None, "model")),
    "wo": (2, ("model", None)),
    "bq": (1, ("model",)),
    "bk": (1, ("model",)),
    "bv": (1, ("model",)),
    "w_gate": (2, (None, "model")),
    "w_up": (2, (None, "model")),
    "w_down": (2, ("model", None)),
    "router": (2, (None, None)),
    "gate": (2, (None, None)),            # shared-expert sigmoid gate (d, 1)
    "scale": (1, (None,)),
    "bias": (1, (None,)),
    "in_proj": (2, (None, "model")),
    "conv_w": (2, ("model", None)),
    "conv_b": (1, ("model",)),
    "A_log": (1, ("model",)),
    "dt_bias": (1, ("model",)),
    "D": (1, ("model",)),
    "norm": (1, ("model",)),              # ssm gated-norm weight (d_inner,)
    "out_proj": (2, ("model", None)),
}

#: MoE expert tensors have an extra leading expert axis (replicated in the
#: baseline tensor-parallel-experts scheme)
_MOE_RULES: Dict[str, Tuple[int, Tuple]] = {
    "w_gate": (3, (None, None, "model")),
    "w_up": (3, (None, None, "model")),
    "w_down": (3, (None, "model", None)),
}

#: beyond-baseline: expert-parallel scheme (experts on "model", §Perf)
_MOE_EXPERT_PARALLEL: Dict[str, Tuple[int, Tuple]] = {
    "w_gate": (3, ("model", None, None)),
    "w_up": (3, ("model", None, None)),
    "w_down": (3, ("model", None, None)),
}


def param_pspecs(cfg, abstract_params=None, *, moe_scheme: str = "tensor") -> Any:
    """PartitionSpec tree congruent with ``init_params(cfg)``."""
    if abstract_params is None:
        abstract_params = T.abstract_params(cfg)
    moe_rules = (_MOE_EXPERT_PARALLEL if moe_scheme == "expert"
                 else _MOE_RULES)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        in_moe = "moe" in names and "shared" not in names
        table = moe_rules if (in_moe and name in moe_rules) else _PARAM_RULES
        if name not in table:
            raise KeyError(f"no partition rule for param path {names}")
        ndim, tail = table[name]
        pad = leaf.ndim - ndim
        assert pad >= 0, (names, leaf.ndim, ndim)
        return P(*((None,) * pad + tuple(tail)))

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def cache_pspecs(cfg, abstract_cache, batch_ax,
                 *, context_parallel: bool = False,
                 model_size: int = 16, kv_shard: str = "auto") -> Any:
    """PartitionSpec tree for the decode cache.

    ``context_parallel``: shard the KV sequence axis on "data" instead of the
    batch axis (long_500k with global_batch=1).

    When ``n_kv_heads % model_size != 0`` the head axis cannot split the
    model axis; replicating KV there is catastrophic at 32k context (e.g.
    qwen1.5-32b: 5.5 TB of KV → 364 GB/device).  The baseline then shards the
    *sequence* axis on "model" instead (sequence-parallel KV, what TPU
    serving stacks do for MHA-KV models).

    ``kv_shard``: "auto" (heads when divisible, else seq), "seq",
    "head_dim", or "heads" (always the head axis — small serving meshes,
    where ``sanitize_specs`` replicates an indivisible head axis instead of
    paying the seq-shard's scattered ring-buffer writes).
    """
    heads_fit = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_size == 0

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name == "len":
            return P() if context_parallel else P(batch_ax)
        if len(names) >= 2 and names[-2] in ("kv", "cross_kv"):
            if leaf.ndim == 3:  # quantized-KV scales: (sites, B, L)
                if context_parallel:
                    return P(None, None, "data")
                if kv_shard == "seq" or (kv_shard == "auto" and not heads_fit):
                    return P(None, batch_ax, "model")
                return P(None, batch_ax, None)
            # KVCache value buffers: (sites, B, L, KH, hd)
            if context_parallel:
                return P(None, None, "data", "model", None)
            if kv_shard == "head_dim":
                return P(None, batch_ax, None, None, "model")
            if kv_shard == "seq" or (kv_shard == "auto" and not heads_fit):
                return P(None, batch_ax, "model", None, None)
            return P(None, batch_ax, None, "model", None)
        if name == "conv":  # (..., B, CH, k)
            pad = leaf.ndim - 3
            bax = None if context_parallel else batch_ax
            return P(*((None,) * pad), bax, "model", None)
        if name == "ssm":  # (..., B, H, P, N)
            pad = leaf.ndim - 4
            bax = None if context_parallel else batch_ax
            return P(*((None,) * pad), bax, "model", None, None)
        raise KeyError(f"no partition rule for cache path {names}")

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)


def batch_pspecs(batch_abstract, batch_ax) -> Any:
    """Specs for token/label/embeds/frames inputs."""

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        if name == "positions":  # (3, B, S)
            return P(None, batch_ax, None)
        if name in ("embeds", "frames"):  # (B, S, d)
            return P(batch_ax, None, None)
        return P(batch_ax, None)  # tokens / labels (B, S)

    return jax.tree_util.tree_map_with_path(spec_for, batch_abstract)


def opt_pspecs(mesh, param_spec_tree, abstract_params):
    """ZeRO-1: optimizer moments additionally sharded over the batch axes.

    For every param spec, the first dimension not already sharded (and
    divisible) picks up the ("pod","data") axes.  Parameters themselves stay
    TP-only (they are needed every step); AdamW moments are touched once per
    step, so sharding them over data costs one reduce-scatter/all-gather pair
    but divides their footprint by the data-parallel degree — without it a
    32B model's f32 moments (17.6 GB/device at TP=16) cannot fit v5e HBM.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = tuple(a for a in ("pod", "data") if a in sizes)
    ddeg = 1
    for a in dax:
        ddeg *= sizes[a]

    def fix(spec, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for dim, ax in enumerate(entries):
            if ax is None and leaf.shape[dim] % ddeg == 0 and leaf.shape[dim] > 0:
                entries[dim] = dax if len(dax) > 1 else dax[0]
                break
        return P(*entries)

    return jax.tree_util.tree_map(
        fix, param_spec_tree, abstract_params,
        is_leaf=lambda x: isinstance(x, P),
    )


def sanitize_specs(mesh, spec_tree, abstract_tree):
    """Drop (replicate) spec entries whose dimension is not divisible by the
    mesh-axis size — e.g. kv_heads=2 cannot split 16-way model parallelism,
    so KV is replicated across the model axis (the real GQA-TP behaviour).
    The roofline table surfaces the cost; §Perf hillclimbs it."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        entries = []
        for dim, ax in enumerate(spec):
            if ax is None:
                entries.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            entries.append(ax if leaf.shape[dim] % n == 0 else None)
        # pad missing trailing dims as replicated
        return P(*entries)

    return jax.tree_util.tree_map(
        lambda s, l: fix(s, l), spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def pallas_decode_support(cfg, mesh) -> Optional[str]:
    """Why the mesh-aware Pallas decode kernel can NOT serve (cfg, mesh) —
    or ``None`` when it can (DESIGN.md §11, docs/kernels.md).

    The supported layout is exactly the one :func:`engine_shardings`
    produces with divisible heads: a single-axis ``("model",)`` TP mesh,
    KV heads on "model" (``kv_shard="heads"``), slots replicated.  There
    the ``shard_map``-wrapped kernel attends each shard's local heads with
    no cross-device collectives and is bit-identical to the single-device
    kernel.  Anything else returns a reason string, prefixed with its
    category (``mesh:`` / ``family:`` / ``layout:``), and the engine keeps
    the loud XLA fallback for it:

    * ``mesh:`` — not a single ``("model",)`` axis (the wrapper does not
      compose with data/pod axes inside one jit).
    * ``family:`` — ssm decode is a recurrent step with no attention read;
      there is no decode kernel to shard.
    * ``layout:`` — head axes do not divide the model axis.  For that
      layout ``sanitize_specs`` *replicates* KV, and the per-shard kernel
      would index the wrong local KV head (it assumes the same GQA ratio
      per shard), so decode must stay on XLA.
    """
    axes = tuple(mesh.axis_names)
    if axes != ("model",):
        return (f"mesh: axes {axes} — the shard_map decode wrapper supports "
                "single-axis ('model',) TP meshes only")
    tp = int(mesh.devices.shape[0])
    if cfg.family == "ssm":
        return ("family: ssm decode is a recurrent step with no attention "
                "read — there is no decode kernel to shard")
    if cfg.n_kv_heads <= 0:
        return "family: config has no KV attention heads"
    if cfg.n_heads % tp or cfg.n_kv_heads % tp:
        return (f"layout: heads ({cfg.n_heads} q / {cfg.n_kv_heads} kv) do "
                f"not divide the 'model' axis (size {tp}) — "
                "engine_shardings replicates KV for this layout, so decode "
                "stays on the XLA path")
    return None


def engine_shardings(mesh, cfg, params, cache
                     ) -> Tuple[Any, Any, NamedSharding]:
    """Sharding trees for a tensor-parallel :class:`InferenceEngine`.

    Returns ``(param_shardings, cache_shardings, replicated)`` for serving
    on ``mesh``: parameters follow the baseline TP rules, the slot cache
    keeps slots **replicated** (``batch_ax=None`` — every device sees every
    slot, so the host-side slot bookkeeping stays sharding-oblivious) with
    KV heads / recurrent state on the "model" axis.  ``kv_shard="heads"``
    pins head-axis KV sharding; when ``n_kv_heads`` does not divide the
    model-axis size, ``sanitize_specs`` replicates that axis (small serving
    meshes prefer replicated KV over the 32k-context seq-shard fallback).
    The cache tree's NamedShardings are shape-agnostic on the slot axis, so
    one tree serves both the persistent ``max_slots`` cache and every
    bucketed prefill sub-cache.

    These head-axis cache shardings are exactly what the mesh-aware Pallas
    decode kernel (``kernels.decode_attention.flash_decode_sharded``)
    expects: its ``shard_map`` in_specs partition Q/K/V on the head axis
    over "model" and replicate the per-slot ``len`` vector, so the KV
    blocks each shard reads are already local — no resharding between the
    cache and the kernel.  :func:`pallas_decode_support` reports whether a
    (cfg, mesh) pair satisfies that contract."""
    model_size = int(dict(zip(mesh.axis_names, mesh.devices.shape))["model"])
    pspec = sanitize_specs(mesh, param_pspecs(cfg, params), params)
    cspec = sanitize_specs(
        mesh,
        cache_pspecs(cfg, cache, None, model_size=model_size,
                     kv_shard="heads"),
        cache)
    return (shardings(mesh, pspec), shardings(mesh, cspec),
            NamedSharding(mesh, P()))
