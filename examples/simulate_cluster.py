"""Cluster-scale simulation (paper §6 at full size, on a laptop).

    PYTHONPATH=src python examples/simulate_cluster.py [--model lam13]

Reproduces a Table-5 slice (FCFS vs ISRTF vs SJF at 1x/3x/5x RPS) and a
Fig-7-style worker-scaling curve on the calibrated discrete-event cluster.
"""
import argparse

from repro.core.metrics import improvement
from repro.simulate import ExperimentConfig, compare_policies, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lam13",
                    choices=["opt6.7", "opt13", "lam7", "lam13", "vic"])
    ap.add_argument("--requests", type=int, default=200)
    args = ap.parse_args()

    print(f"== Table 5 slice: {args.model}, batch 4, 200 prompts ==")
    for rps in (1.0, 3.0, 5.0):
        cfg = ExperimentConfig(model=args.model, n_requests=args.requests,
                               batch_size=4, rps_multiple=rps, seed=7)
        res = compare_policies(cfg, ("fcfs", "isrtf", "sjf"), n_trials=3)
        print(f"  RPS {rps:.1f}x: FCFS {res['fcfs']['jct_mean']:7.1f}s  "
              f"ISRTF {res['isrtf']['jct_mean']:7.1f}s  "
              f"SJF {res['sjf']['jct_mean']:7.1f}s  "
              f"(ISRTF {improvement(res['fcfs'], res['isrtf']):+.1f}%)")

    print("\n== Fig 7: worker scaling (ISRTF) ==")
    for workers in (1, 2, 4, 8):
        cfg = ExperimentConfig(model=args.model, n_requests=args.requests,
                               batch_size=4, n_nodes=workers,
                               rate_override=0.3 * workers, seed=7)
        m = run_experiment(cfg)
        print(f"  {workers} workers @ {0.3*workers:.1f} req/s: "
              f"JCT {m['jct_mean']:7.1f}s  queue "
              f"{m['queuing_delay_mean']:6.2f}s")


if __name__ == "__main__":
    main()
