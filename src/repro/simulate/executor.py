"""Discrete-event cluster executor.

Implements the frontend's ``Backend`` ABC with virtual time and the
calibrated latency model.  Replays each job's pre-generated response token
stream (the simulator never invents tokens — ground truth lives with the
workload generator), tracks per-node KV residency for preemption/recompute
accounting, and enforces the Appendix-A memory capacity.

Clusters may be *heterogeneous*: ``node_profiles`` maps node ids to their
own :class:`~repro.simulate.profiles.ModelProfile` (e.g. fast and slow pods
mixing two calibrated entries); unmapped nodes fall back to ``profile``.
Each node's latency AND its Appendix-A KV capacity come from its own
profile, so placement policies are evaluated where nodes actually differ.
A job that resumes on a *different* node after preemption or migration is
simply not resident there — it pays the normal cold-start KV recompute.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.frontend import Backend, ExecResult
from repro.core.job import Job
from repro.simulate.profiles import SCHED_OVERHEAD_MS, ModelProfile


@dataclass
class SimExecutor(Backend):
    profile: ModelProfile
    #: include the paper's measured 11.04 ms scheduling overhead per iteration
    sched_overhead_s: float = SCHED_OVERHEAD_MS / 1000.0
    #: global cap on resident KV tokens per node; None = each node's own
    #: Appendix-A capacity (per-profile on heterogeneous clusters)
    kv_capacity_tokens: Optional[int] = None
    #: heterogeneous clusters: node id -> that pod's profile (latency and
    #: KV capacity); nodes absent from the map run ``profile``
    node_profiles: Optional[Dict[int, ModelProfile]] = None

    _resident: Dict[int, Set[int]] = field(default_factory=dict)
    _resident_tokens: Dict[int, Dict[int, int]] = field(default_factory=dict)
    mem_preemptions: int = 0

    def __post_init__(self):
        if self.kv_capacity_tokens is None and not self.node_profiles:
            # homogeneous cluster: materialise the single capacity up front
            # (kept for introspection; heterogeneous runs stay per-node)
            self.kv_capacity_tokens = self.profile.kv_capacity_tokens()

    # ------------------------------------------------------------------ #
    def profile_of(self, node: int) -> ModelProfile:
        if self.node_profiles:
            return self.node_profiles.get(node, self.profile)
        return self.profile

    def node_token_cost(self, n_nodes: int) -> Dict[int, float]:
        """Seconds per generated token per node (batch-1 decode rate) — the
        calibrated cost map the ``least_eta`` placement policy consumes."""
        return {n: self.profile_of(n).decode_ms_1 / 1000.0
                for n in range(n_nodes)}

    def _capacity_of(self, node: int) -> int:
        if self.kv_capacity_tokens is not None:
            return self.kv_capacity_tokens
        return self.profile_of(node).kv_capacity_tokens()

    # ------------------------------------------------------------------ #
    def evict(self, node: int, job: Job) -> None:
        self._resident.setdefault(node, set()).discard(job.job_id)
        self._resident_tokens.setdefault(node, {}).pop(job.job_id, None)

    def resident_token_count(self, node: int) -> int:
        return sum(self._resident_tokens.get(node, {}).values())

    def capacity(self, node: int) -> Optional[int]:
        # job count is unbounded in the simulator; residency is bounded by
        # KV *tokens* (Appendix-A memory model), enforced inside execute()
        return None

    def free_capacity(self, node: int) -> Optional[int]:
        return None

    # ------------------------------------------------------------------ #
    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float) -> ExecResult:
        prof = self.profile_of(node)
        res = self._resident.setdefault(node, set())
        res_toks = self._resident_tokens.setdefault(node, {})
        b = len(jobs)

        prefill_ms = 0.0
        for job in jobs:
            if job.job_id not in res:
                # cold start or resumed-after-preemption/migration: recompute
                # the KV cache for everything generated so far (vLLM
                # recompute mode)
                n = len(job.prompt_tokens) + job.tokens_generated
                prefill_ms += prof.prefill_ms(b, n)
                res.add(job.job_id)
                res_toks[job.job_id] = n

        tokens_out: List[List[int]] = []
        finished: List[bool] = []
        max_new = 0
        for job in jobs:
            if len(job.output_tokens) < job.true_output_len:
                # the simulator REPLAYS ground-truth streams — a job whose
                # stream is shorter than its declared length would stop
                # progressing once the stream runs dry and spin the event
                # loop forever; fail loudly instead (the live engine has no
                # such requirement: it invents tokens)
                raise ValueError(
                    f"job {job.job_id}: output_tokens has "
                    f"{len(job.output_tokens)} tokens but true_output_len="
                    f"{job.true_output_len}; the simulator cannot replay it "
                    "(use repro.data.workload streams or fill output_tokens)")
            remaining = job.true_output_len - job.tokens_generated
            n_new = min(window, remaining)
            start = job.tokens_generated
            tokens_out.append(job.output_tokens[start : start + n_new])
            finished.append(n_new >= remaining)
            res_toks[job.job_id] = res_toks.get(job.job_id, 0) + n_new
            max_new = max(max_new, n_new)

        decode_ms = max_new * prof.decode_ms(b)
        duration = self.sched_overhead_s + (prefill_ms + decode_ms) / 1000.0

        # Appendix-A memory pressure: if resident KV exceeds capacity, evict
        # the largest non-batch residents (counted as memory preemptions)
        cap = self._capacity_of(node)
        total = sum(res_toks.values())
        if total > cap:
            batch_ids = {j.job_id for j in jobs}
            evictable = sorted(
                ((t, jid) for jid, t in res_toks.items()
                 if jid not in batch_ids),
                reverse=True,
            )
            for t, jid in evictable:
                if total <= cap:
                    break
                res.discard(jid)
                res_toks.pop(jid)
                total -= t
                self.mem_preemptions += 1

        return ExecResult(duration, tokens_out, finished)
