"""Training substrate: AdamW, schedules, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    schedule_lr,
    train,
)


def test_adamw_converges_quadratic():
    params = {"x": jnp.array(5.0)}
    cfg = AdamWConfig(lr=0.1, schedule="constant", weight_decay=0.0,
                      warmup_steps=0)
    state = adamw_init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert abs(float(params["x"])) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    norm = float(global_norm(tree))
    clipped, reported = clip_by_global_norm(tree, max_norm=1.0)
    assert float(reported) == pytest.approx(norm)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine", min_lr_ratio=0.1)
    lr0 = float(schedule_lr(cfg, jnp.asarray(0)))
    lr5 = float(schedule_lr(cfg, jnp.asarray(5)))
    lr10 = float(schedule_lr(cfg, jnp.asarray(10)))
    lr_end = float(schedule_lr(cfg, jnp.asarray(110)))
    assert lr0 == 0.0 and 0 < lr5 < lr10 == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, abs=1e-6)


def test_trainable_mask_freezes():
    params = {"train": jnp.array(1.0), "frozen": jnp.array(1.0)}
    mask = {"train": True, "frozen": False}
    cfg = AdamWConfig(lr=0.5, schedule="constant", warmup_steps=0)
    state = adamw_init(params)
    grads = {"train": jnp.array(1.0), "frozen": jnp.array(1.0)}
    params, state, _ = adamw_update(cfg, grads, state, params,
                                    trainable_mask=mask)
    assert float(params["frozen"]) == 1.0
    assert float(params["train"]) != 1.0


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4)}}
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    save_checkpoint(d, 7, tree, metadata={"note": "hi"})
    assert latest_step(d) == 7
    restored, meta = restore_checkpoint(d, 7, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert meta["note"] == "hi"


def test_checkpoint_gc(tmp_path):
    tree = {"w": jnp.zeros(2)}
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    for s in range(6):
        save_checkpoint(d, s, tree, keep=3)
    kept = sorted(os.listdir(d))
    assert len(kept) == 3 and kept[-1] == "step_00000005"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, {"w": jnp.zeros((3, 3))})
