"""Serving launcher — the Kubernetes-pod entrypoint analogue.

Assembles the full ELIS stack from CLI args: N backend workers (each an
InferenceEngine on the selected ``--arch``, reduced configs on CPU), the
frontend scheduler with the chosen policy, and either a trace file from
``repro.launch.generate`` or a synthetic stream.

    python -m repro.launch.serve --arch qwen2-1.5b --policy isrtf \
        --workers 2 --trace trace.jsonl
    python -m repro.launch.serve --arch mamba2-130m --policy isrtf --n 12
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import (
    BGEPredictor,
    ElisServer,
    FrontendConfig,
    OraclePredictor,
    PLACEMENTS,
    PREEMPT_POLICIES,
    PredictorConfig,
    PreemptionConfig,
    Request,
    RequestOptions,
    SchedulerConfig,
    summarize,
    wrap_calibration,
)
from repro.core.metrics import fairness_ratio, summarize_by_tenant
from repro.data import GammaArrivals, WorkloadGenerator
from repro.data.workload import (
    SCENARIOS,
    build_scale_workload,
    scale_workload_requests,
)
from repro.engine import (
    EngineConfig,
    EngineExecutor,
    InferenceEngine,
    make_tp_pods,
)
from repro.models import init_params
from repro.models.encoder import EncoderArchConfig
from repro.training import latest_step, restore_checkpoint


def parse_mesh(spec: str):
    """Parse a ``--mesh`` shape string into ``(D, M)``.

    The only accepted form is ``DxM`` — exactly two ``x``-separated
    positive integers (e.g. ``2x4``).  Anything else (``2x``, ``2x3x4``,
    ``ax4``, ``0x4``, ``2x-1``) raises :class:`ValueError` naming the
    offending spec and the expected format, so a typo dies at launch
    instead of materialising a mis-shaped device mesh.
    """
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.strip() for p in parts):
        raise ValueError(
            f"--mesh wants exactly two 'x'-separated fields DxM "
            f"(e.g. 2x4), got {spec!r}")
    try:
        d, m = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"--mesh wants integer dimensions DxM (e.g. 2x4), "
            f"got {spec!r}") from None
    if d < 1 or m < 1:
        raise ValueError(
            f"--mesh dimensions must be positive integers DxM "
            f"(e.g. 2x4), got {spec!r}")
    return d, m


def load_requests(args):
    if args.scenario:
        if args.trace:
            sys.exit("--scenario and --trace are mutually exclusive")
        rng = np.random.RandomState(args.seed)
        w = build_scale_workload(args.scenario, args.n, args.rate, rng)
        # scenario workloads carry tenant / priority / deadline per request;
        # from_workload forwards them into RequestOptions so the frontend's
        # priority banding and SLO accounting see them
        reqs = [Request.from_workload(r) for r in scale_workload_requests(w)]
        return reqs, dict(w.slo_targets)
    if args.trace:
        reqs = []
        for line in open(args.trace):
            r = json.loads(line)
            reqs.append(Request(
                request_id=r["request_id"], prompt=r["prompt"],
                prompt_tokens=r["prompt_tokens"],
                arrival_time=r["arrival_time"],
                true_output_len=r.get("max_tokens", args.max_output),
                options=RequestOptions(max_tokens=args.max_output,
                                       deadline=r.get("deadline")),
            ))
        return reqs, {}
    gen = WorkloadGenerator(seed=args.seed)
    rng = np.random.RandomState(args.seed)
    times = GammaArrivals().rate_scaled(args.rate).sample_arrival_times(
        args.n, rng)
    reqs = []
    for i, t in enumerate(times):
        r = gen.sample_request()
        reqs.append(Request(
            request_id=i, prompt=r.prompt, prompt_tokens=r.prompt_tokens,
            arrival_time=float(t), true_output_len=r.true_output_len,
            options=RequestOptions(max_tokens=args.max_output)))
    return reqs, {}


def probe_node_costs(executor, reps: int):
    """Fit per-pod token costs live before serving: run ``reps`` probe
    windows per (batch, window) cell on every pod and least-squares the
    measurements (``calibrated_node_profiles``).  The first window of each
    shape pays XLA compile and is dropped by the fit — probing doubles as
    warmup, so serving never pays those compiles mid-traffic."""
    from repro.core.job import Job

    jid = 10 ** 9  # out of any real request-id range
    for node, eng in executor.engines.items():
        batches = sorted({1, min(2, eng.cfg.max_slots)})
        for _ in range(reps + 1):  # +1: the dropped compile window
            for batch in batches:
                for window in (4, 16):
                    jobs = [Job(job_id=jid + i, prompt="probe",
                                prompt_tokens=[7, 8, 9, 10],
                                arrival_time=0.0)
                            for i in range(batch)]
                    executor.execute(node, jobs, window, now=0.0)
                    for j in jobs:
                        executor.evict(node, j)
    costs = executor.node_token_cost()
    return costs


def build_predictor(args):
    if args.predictor == "oracle":
        base = OraclePredictor()
    else:
        cfg = PredictorConfig(
            encoder=EncoderArchConfig(d_model=128, n_heads=4, n_layers=3,
                                      d_ff=256, max_len=192),
            n_fc_layers=8, fc_hidden=256, max_len=192,
        )
        base = BGEPredictor(cfg, seed=0)
        if args.predictor_ckpt:
            step = latest_step(args.predictor_ckpt)
            if step is None:
                sys.exit(f"no checkpoint in {args.predictor_ckpt}")
            base.params, _ = restore_checkpoint(args.predictor_ckpt, step,
                                                base.params)
    # serving-time calibration wrappers compose over any base predictor;
    # the live loop feeds them finish-time observations (ELIS frontend
    # calls predictor.observe as requests complete)
    cal = None if args.calibrate == "none" else args.calibrate
    return wrap_calibration(base, cal)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(list_archs()))
    ap.add_argument("--policy", default="isrtf",
                    choices=["fcfs", "sjf", "isrtf", "mlfq"])
    ap.add_argument("--predictor", default="oracle",
                    choices=["oracle", "bge"])
    ap.add_argument("--predictor-ckpt", default=None,
                    help="restore a trained BGE predictor (train_predictor.py)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the serving fleet over a DxM data×model "
                         "device mesh: D tensor-parallel pods of M devices "
                         "each (supersedes --workers; needs D*M devices — "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--pods", type=int, default=None,
                    help="with --mesh DxM: use only the first N of the D "
                         "data rows as live pods (default: all D)")
    ap.add_argument("--placement", default="least_jobs",
                    choices=sorted(PLACEMENTS),
                    help="cluster placement policy consulted at arrival "
                         "(prediction-aware modes need a length predictor; "
                         "least_eta uses per-pod token costs fitted by "
                         "--probe-nodes, else assumes uniform speed)")
    ap.add_argument("--probe-nodes", type=int, default=0, metavar="REPS",
                    help="before serving, run REPS calibration windows per "
                         "pod and fit per-node token costs from the live "
                         "measurements (wired into least_eta placement)")
    ap.add_argument("--rebalance", action="store_true",
                    help="steal queued jobs across workers when the "
                         "predicted-work imbalance exceeds the threshold")
    ap.add_argument("--rebalance-threshold", type=float, default=200.0,
                    help="predicted-token imbalance that triggers stealing")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--repredict-every", type=int, default=1,
                    help="full predictor re-score every N windows (between "
                         "them cached predictions decay by progress)")
    ap.add_argument("--calibrate", default="none",
                    choices=["none", "ema", "conformal", "ema+conformal"],
                    help="serving-time calibration over the predictor: EMA "
                         "multiplicative debiasing and/or conformal "
                         "quantiles from finish-time residuals")
    ap.add_argument("--risk-quantile", type=float, default=None,
                    help="rank ISRTF on this calibrated upper quantile of "
                         "the predicted remaining length instead of the "
                         "point estimate (e.g. 0.9 hedges against "
                         "underestimates)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="TOKENS",
                    help="chunked prefill: ingest each prompt in chunks of "
                         "this many tokens, at most one chunk per "
                         "scheduling window, interleaved with decode "
                         "(default: one-shot prefill)")
    ap.add_argument("--preempt-policy", default="recompute",
                    choices=list(PREEMPT_POLICIES),
                    help="what preemption does to the victim's KV cache: "
                         "recompute = evict and re-prefill on resume; "
                         "swap = offload to host memory and restore; "
                         "auto = per-victim break-even between the two on "
                         "predicted remaining length")
    ap.add_argument("--swap-bandwidth", type=float, default=16e9,
                    metavar="BYTES_PER_S",
                    help="host<->device KV transfer bandwidth the swap "
                         "preemption tier is priced with")
    ap.add_argument("--swap-latency", type=float, default=5e-4, metavar="S",
                    help="fixed per-transfer latency of one KV swap leg")
    ap.add_argument("--swap-pool", type=int, default=None, metavar="TOKENS",
                    help="watermark bounding each engine's host KV swap "
                         "pool, in stashed context tokens; over-watermark "
                         "swap-outs evict the coldest stashed victims to "
                         "recompute-fallback (default: unbounded)")
    ap.add_argument("--max-output", type=int, default=32)
    ap.add_argument("--trace", default=None)
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="run a registered traffic scenario instead of the "
                         "default synthetic stream: --n requests at --rate "
                         "mean req/s, with per-tenant arrival processes, "
                         "priority classes and SLO targets; the summary "
                         "gains per-tenant metrics and a JCT fairness ratio")
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--rate", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-preemption", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ecfg = EngineConfig(
        max_slots=args.slots, max_len=512, max_output=args.max_output,
        eos_id=-1, respect_job_max=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.prefill_chunk is not None and args.prefill_chunk < 1:
        sys.exit(f"--prefill-chunk must be >= 1, got {args.prefill_chunk}")
    if args.mesh:
        try:
            d, m = parse_mesh(args.mesh)
        except ValueError as e:
            sys.exit(str(e))
        n_pods = args.pods if args.pods is not None else d
        if not 1 <= n_pods <= d:
            sys.exit(f"--pods {n_pods} outside the mesh's {d} data rows")
        args.workers = n_pods
        engines = make_tp_pods(cfg, params, ecfg, n_pods=n_pods, tp=m)
        print(f"[serve] {n_pods} TP={m} pod(s) x {args.slots} slots over "
              f"{n_pods * m}/{len(jax.devices())} devices, {cfg.arch_id}, "
              f"policy={args.policy}", file=sys.stderr)
    else:
        engines = {n: InferenceEngine(cfg, params, ecfg)
                   for n in range(args.workers)}
        print(f"[serve] {args.workers} worker(s) x {args.slots} slots, "
              f"{cfg.arch_id}, policy={args.policy}", file=sys.stderr)
    # prediction-aware placement / rebalancing consume length predictions
    # even when the ordering policy (fcfs/mlfq) does not; rebalancing is
    # meaningful only across workers
    if args.rebalance and args.workers < 2:
        print("[serve] --rebalance ignored with a single worker",
              file=sys.stderr)
    needs_predictor = (args.policy in ("sjf", "isrtf")
                       or args.placement != "least_jobs"
                       or (args.rebalance and args.workers > 1))
    predictor = build_predictor(args) if needs_predictor else None
    executor = EngineExecutor(engines,
                              swap_bandwidth_bytes_s=args.swap_bandwidth,
                              swap_latency_s=args.swap_latency,
                              swap_pool_tokens=args.swap_pool)
    node_token_cost = None
    if args.probe_nodes > 0:
        node_token_cost = probe_node_costs(executor, args.probe_nodes)
        executor.window_log.clear()  # probe windows are not served traffic
        print("[serve] probed node token costs: "
              + "  ".join(f"{n}={c * 1000:.2f}ms/tok"
                          for n, c in sorted(node_token_cost.items())),
              file=sys.stderr)
    server = ElisServer(
        FrontendConfig(
            n_nodes=args.workers,
            scheduler=SchedulerConfig(policy=args.policy, window=args.window,
                                      batch_size=args.slots,
                                      repredict_every=args.repredict_every,
                                      risk_quantile=args.risk_quantile,
                                      prefill_chunk=args.prefill_chunk),
            preemption=PreemptionConfig(enabled=not args.no_preemption,
                                        policy=args.preempt_policy,
                                        swap_pool_tokens=args.swap_pool),
            placement=args.placement,
            node_token_cost=node_token_cost,
            rebalance=args.rebalance,
            rebalance_threshold=args.rebalance_threshold,
            # the live engine only reveals a request's length at finish —
            # calibration learns from finish observations, never from the
            # trace's nominal max_tokens
            observe_in_flight=False,
        ),
        predictor,
        executor,
    )
    requests, slo_targets = load_requests(args)
    for r in requests:
        server.submit(r)
    responses = server.drain()
    for r in sorted(responses, key=lambda r: r.request_id):
        rec = {
            "request_id": r.request_id,
            "node": r.node,
            "status": r.status.value,
            "n_tokens": r.n_tokens,
            "jct_s": round(r.jct(), 3),
            "queuing_delay_s": round(r.queuing_delay, 3),
            "preemptions": r.n_preemptions,
            "migrations": r.n_migrations,
        }
        if args.scenario:
            rec["tenant"] = r.tenant
        print(json.dumps(rec))
    finished = [r for r in responses if r.ok]
    m = summarize(finished)
    print(f"[serve] mean JCT {m['jct_mean']:.2f}s  queue "
          f"{m['queuing_delay_mean']:.2f}s  throughput "
          f"{m['throughput_rps']:.2f} req/s  "
          f"placement={args.placement} "
          f"migrations={server.frontend.migrations}  "
          f"({len(finished)}/{len(responses)} finished)", file=sys.stderr)
    ec = executor.counters()
    if ec["chunk_dispatches"] or ec["swapouts"]:
        print(f"[serve] chunk_dispatches={ec['chunk_dispatches']} "
              f"(traces {ec['chunk_traces']})  "
              f"swapouts={ec['swapouts']} swapins={ec['swapins']}  "
              f"resume_prefill_tokens={ec['resume_context_tokens']}",
              file=sys.stderr)
    if args.scenario:
        tenants = summarize_by_tenant(finished, slo_targets)
        # expiry is a per-tenant outcome (deadline-heavy agent traffic):
        # count over ALL responses — expired ones never reach `finished`
        submitted, expired = {}, {}
        for r in responses:
            submitted[r.tenant] = submitted.get(r.tenant, 0) + 1
            if r.status.value == "expired":
                expired[r.tenant] = expired.get(r.tenant, 0) + 1
        for t, tm in sorted(tenants.items()):
            slo = (f"  slo_attainment {tm['slo_attainment']:.2f}"
                   if "slo_attainment" in tm else "")
            exp = expired.get(t, 0) / max(submitted.get(t, 0), 1)
            print(f"[serve]   tenant={t:<12} n={tm['n']:<5} mean JCT "
                  f"{tm['jct_mean']:.2f}s  p99 {tm['jct_p99']:.2f}s"
                  f"{slo}  expiry_rate {exp:.2f}", file=sys.stderr)
        fair = fairness_ratio(
            {t: tm["jct_mean"] for t, tm in tenants.items()})
        print(f"[serve]   fairness(max/min mean JCT) {fair:.2f}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
