"""Core transformer building blocks (pure JAX, pytree params).

All parameters are plain nested dicts of ``jnp.ndarray`` so they shard
naturally under pjit and stack naturally under ``lax.scan``.  Every block is
expressed as an ``init_*`` function (returns the param subtree) plus an apply
function (pure, takes the subtree first).

Attention supports:
  * GQA (n_kv_heads < n_heads) via broadcast within the head-group axis,
  * causal masks with query offsets (decode),
  * sliding-window (SWA) masks,
  * ring-buffer KV caches for bounded-window decode (long_500k carve-in),
  * dispatch to the Pallas kernels (``attn_impl="pallas"``) or the pure-XLA
    einsum path (``attn_impl="xla"``, the oracle).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


@jax.tree_util.register_pytree_node_class
class KVCache:
    """K/V buffers with *static* ring-buffer and quantization flags.

    ``ring``/``quantized`` are pytree aux-data (not leaves), so they stay
    Python bools under ``jit`` — resolved at trace time.
    Buffers are (..., batch, buf_len, kv_heads, head_dim); a leading layer/
    site axis is present in the stacked model cache and absent inside a
    per-layer scan body.

    Quantized mode (int8 KV — beyond-paper §Perf optimization): buffers are
    int8 with per-(batch, slot) fp32 scales ``k_scale``/``v_scale`` of shape
    (..., batch, buf_len); values are symmetric-quantized at write
    (scale = amax/127 over the token's heads×dims) and dequantized fused
    into the attention read.
    """

    def __init__(self, k, v, ring: bool = False, k_scale=None, v_scale=None):
        self.k = k
        self.v = v
        self.ring = ring
        self.k_scale = k_scale
        self.v_scale = v_scale

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def tree_flatten(self):
        # fixed 4-child arity — None scales are empty subtrees, so the
        # treedef stays consistent when JAX reconstructs with placeholders
        return (self.k, self.v, self.k_scale, self.v_scale), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        k, v, ks, vs = children
        return cls(k, v, ring, ks, vs)

    def __repr__(self):
        return (f"KVCache(k={getattr(self.k, 'shape', None)}, "
                f"ring={self.ring}, quantized={self.quantized})")


def quantize_kv(x: jnp.ndarray):
    """x (B, S, KH, D) -> (int8 values, fp32 scales (B, S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-1, -2))
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None, None]).astype(dtype)


def masked_span_write(buf: jnp.ndarray, start: jnp.ndarray, val: jnp.ndarray,
                      valid_len: jnp.ndarray) -> jnp.ndarray:
    """Write ``val`` (B, C, ...) into ``buf`` (B, L, ...) at rows
    ``[start, start + valid_len)`` per batch element.  Positions beyond
    ``valid_len`` (chunk padding) are dropped via out-of-bounds scatter
    (``mode="drop"``), so the existing buffer content there stays
    bit-identical — the chunked-prefill analogue of
    :func:`masked_row_write`'s frozen-slot no-op."""
    b, c = val.shape[0], val.shape[1]
    idx = start[:, None] + jnp.arange(c)[None, :]          # (B, C)
    ok = jnp.arange(c)[None, :] < valid_len[:, None]
    safe = jnp.where(ok, idx, buf.shape[1])                # OOB -> dropped
    rows = jnp.arange(b)[:, None]
    return buf.at[rows, safe].set(val, mode="drop")


def masked_row_write(buf: jnp.ndarray, slot: jnp.ndarray, val: jnp.ndarray,
                     active=None) -> jnp.ndarray:
    """Write ``val`` (B, ...) into ``buf`` (B, L, ...) at per-row position
    ``slot`` (B,).  Rows with ``active=False`` keep their previous value —
    the no-op that lets frozen decode slots (EOS-finished or simply
    unoccupied) share a dispatch with live slots without corrupting their
    cache.  The select touches one position per row, so the masked write
    costs a (B, ...) gather, not a whole-buffer copy."""
    rows = jnp.arange(buf.shape[0])
    if active is not None:
        keep = active.reshape((-1,) + (1,) * (val.ndim - 1))
        val = jnp.where(keep, val, buf[rows, slot])
    return buf.at[rows, slot].set(val)


# --------------------------------------------------------------------------- #
# Initialisers
# --------------------------------------------------------------------------- #


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(orig)


def init_layernorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(orig)


def apply_norm(cfg, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


def init_norm(cfg, dim: int, dtype=jnp.float32) -> Params:
    if cfg.norm == "layernorm":
        return init_layernorm(dim, dtype)
    return init_rmsnorm(dim, dtype)


# --------------------------------------------------------------------------- #
# Rotary position embeddings (RoPE and M-RoPE)
# --------------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (..., seq) -> cos/sin of shape (..., seq, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(angles), jnp.sin(angles)


def mrope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]):
    """M-RoPE (Qwen2-VL): 3D positions (3, batch, seq); frequency bands are
    partitioned into (temporal, height, width) sections.  Returns cos/sin of
    shape (batch, seq, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)  # (half,)
    # angles per axis: (3, batch, seq, half)
    angles = positions[..., None].astype(jnp.float32) * inv
    half = head_dim // 2
    t, h, w = sections
    assert t + h + w == half, (sections, half)
    sel = jnp.concatenate(
        [jnp.zeros((t,), jnp.int32), jnp.ones((h,), jnp.int32),
         jnp.full((w,), 2, jnp.int32)]
    )  # (half,) in {0,1,2}
    # gather: angles is (3, B, S, half); choose axis sel[i] for frequency i
    picked = angles[sel, ..., jnp.arange(half)]  # (half, B, S)
    picked = jnp.moveaxis(picked, 0, -1)  # (B, S, half)
    return jnp.cos(picked), jnp.sin(picked)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, D); cos/sin (B, S, D//2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def positional_cos_sin(cfg, positions: jnp.ndarray):
    """positions: (B, S) for rope / (3, B, S) for mrope -> (cos, sin) or None."""
    if cfg.rope_type == "rope":
        return rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    if cfg.rope_type == "mrope":
        if positions.ndim == 2:  # text-only fallback: replicate across axes
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    return None


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #


def init_attention(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


# --------------------------------------------------------------------------- #
# Activation-sharding hints (§Perf)
# --------------------------------------------------------------------------- #

_ATTN_HEAD_AXIS = None


class attn_head_sharding:
    """Context: constrain q/k/v activations to head-sharding on the given
    mesh axis (padded when n_heads isn't divisible).  Fixes the GSPMD
    pathology where flat-projection shards straddle head boundaries and the
    partitioner all-reduces partial attention scores (see EXPERIMENTS.md
    §Perf HC2: a 120 GB/step all-reduce of f32[B,H,32k,32k])."""

    def __init__(self, axis: str = "model"):
        self.axis = axis

    def __enter__(self):
        global _ATTN_HEAD_AXIS
        self._prev = _ATTN_HEAD_AXIS
        _ATTN_HEAD_AXIS = self.axis
        return self

    def __exit__(self, *a):
        global _ATTN_HEAD_AXIS
        _ATTN_HEAD_AXIS = self._prev


def _constrain_heads(x: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, D) -> head-sharded on the hinted axis (no-op otherwise)."""
    if _ATTN_HEAD_AXIS is None:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(None, None, _ATTN_HEAD_AXIS, None)
    )


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KH, D) -> (B, S, H, D) by repeating each kv head H/KH times."""
    b, s, kh, d = k.shape
    rep = n_heads // kh
    if rep == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, rep, d))
    return k.reshape(b, s, n_heads, d)


def sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    window: Optional[int] = None,
    ring_offset=None,
) -> jnp.ndarray:
    """Scaled dot-product attention with GQA, decode offsets and SWA.

    q: (B, Sq, H, D); k/v: (B, Skv, KH, D).
    q_offset: absolute position of q[0] (scalar; decode passes cur_len).
    kv_len: number of valid cache entries (scalar) — positions >= kv_len masked.
    window: sliding-window size; keys older than (q_pos - window + 1) masked.
    ring_offset: if the KV buffer is a ring buffer, absolute position of
      buffer slot 0 is ``ring_offset`` — key absolute positions are
      ``ring_offset + ((slot - ring_offset) mod Skv)``... we instead pass the
      precomputed absolute key positions directly when ringed (see caller).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if sq > 1:
        # full-sequence (prefill/train) only: constraining the cache-sized
        # K/V of a one-token decode forces a whole-cache reshard per layer
        # per step (measured 25x collective regression — EXPERIMENTS §Perf)
        q = _constrain_heads(q)
        k = _constrain_heads(_expand_kv(k, h))
        v = _constrain_heads(_expand_kv(v, h))
    else:
        k = _expand_kv(k, h)
        v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale

    # q_offset may be scalar or per-batch (B,) — decode slots advance
    # independently under continuous batching
    q_off = jnp.asarray(q_offset)
    q_pos = jnp.arange(sq)[None, :] + q_off.reshape(-1, 1)  # (1|B, Sq)
    q_pos = q_pos[:, None, :, None]  # (1|B, 1, Sq, 1)
    if ring_offset is not None:
        k_pos = ring_offset  # precomputed absolute positions (Skv,) or (B,Skv)
        if k_pos.ndim == 1:
            k_pos = k_pos[None, :]
        k_pos = k_pos[:, None, None, :]  # (B,1,1,Skv)
    else:
        k_pos = jnp.arange(skv)[None, None, None, :]

    mask = jnp.ones_like(scores, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if kv_len is not None:
        valid = jnp.arange(skv)[None, None, None, :] < jnp.asarray(kv_len).reshape(-1, 1, 1, 1)
        mask &= valid
    if window is not None:
        mask &= k_pos > (q_pos - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with ring padding) -> zeros not nans
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_block(
    p: Params,
    cfg,
    x: jnp.ndarray,
    cos_sin,
    *,
    cache: Optional[KVCache] = None,
    cur_index=None,
    attn_impl: str = "xla",
    active=None,
    valid_len=None,
    mesh=None,
) -> Tuple[jnp.ndarray, object]:
    """Full attention block: proj -> rope -> (cache update) -> sdpa -> out proj.

    Training/prefill: ``cache is None`` -> full-sequence causal attention,
    returns (out, (k, v)) for cache seeding.
    Decode: ``cache`` is a :class:`KVCache` with buffers (B, L, KH, D) and
    ``cur_index`` is the per-slot token count; x is (B, 1, d_model).
    ``active`` (B,) bool, decode only: rows marked inactive skip their KV
    write (their buffer row is bit-identical afterwards) — the caller
    freezes their ``len`` to match, so a frozen slot's cache is untouched
    by the dispatch it shared with live slots.
    Tensor-parallel decode: ``mesh`` (a single-axis ``("model",)`` mesh)
    makes the ``attn_impl="pallas"`` decode read run the flash-decode
    kernel ``shard_map``-ped over the model axis — Q/KV heads partitioned
    exactly as ``engine_shardings`` places them, per-slot lengths
    replicated, no collective inside the kernel (docs/kernels.md).
    Callers must only pass a mesh when both head axes divide it.
    Chunked prefill: ``cache`` given AND x is (B, C>1, d) — the C fresh
    tokens start at absolute position ``cur_index`` (B,) and only the first
    ``valid_len`` (B,) of them are real (the rest is bucket padding).  The
    valid span's K/V are span-written into the buffer and the chunk's
    queries attend over the whole buffer under a ``kv_len`` mask — masked
    positions contribute exactly +0.0 after softmax (the same invariant
    batched bucketed prefill already relies on), so chunked prefill is
    bit-identical to one-shot prefill.  Ring/quantized caches are rejected:
    a ring write is position-destructive and a quantized read would
    dequantize the prefix while one-shot prefill attends the unquantized
    fresh K/V, breaking bit-identity.
    """
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    if cos_sin is not None:
        cos, sin = cos_sin
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    if s > 1:
        q = _constrain_heads(q)

    window = cfg.swa_window if cfg.attention_type == "swa" else None

    if cache is None:
        if attn_impl == "pallas" and s > 1:
            from repro.kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=True, window=window)
        else:
            out = sdpa(q, k, v, causal=True, window=window)
        new_kv = (k, v)
    else:
        kbuf, vbuf = cache.k, cache.v
        L = kbuf.shape[1]
        ringed = cache.ring
        # cur_index may be scalar or per-batch (B,) under continuous batching
        cur = jnp.broadcast_to(jnp.asarray(cur_index), (b,))
        if s > 1:
            # chunked prefill over a partially-filled cache (see docstring)
            if ringed or cache.quantized:
                raise ValueError(
                    "chunked prefill requires a dense unquantized KV cache "
                    "(ring/SWA and int8 caches fall back to one-shot prefill)")
            valid = jnp.broadcast_to(
                jnp.asarray(s if valid_len is None else valid_len), (b,))
            kbuf = masked_span_write(kbuf, cur, k, valid)
            vbuf = masked_span_write(vbuf, cur, v, valid)
            out = sdpa(q, kbuf, vbuf, causal=True, q_offset=cur,
                       kv_len=cur + valid, window=window)
            out = out.reshape(b, s, h * hd)
            return out @ p["wo"], KVCache(kbuf, vbuf, False)
        slot = cur % L if ringed else cur
        if cache.quantized:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kbuf = masked_row_write(kbuf, slot, kq[:, 0], active)
            vbuf = masked_row_write(vbuf, slot, vq[:, 0], active)
            k_sc = masked_row_write(cache.k_scale, slot, ks[:, 0], active)
            v_sc = masked_row_write(cache.v_scale, slot, vs[:, 0], active)
            kread = dequantize_kv(kbuf, k_sc, q.dtype)
            vread = dequantize_kv(vbuf, v_sc, q.dtype)
        else:
            kbuf = masked_row_write(kbuf, slot, k[:, 0], active)
            vbuf = masked_row_write(vbuf, slot, v[:, 0], active)
            k_sc = v_sc = None
            kread, vread = kbuf, vbuf
        if ringed:
            # absolute position of each buffer slot given cur tokens seen:
            # slot i holds the largest position p <= cur with p % L == i
            idx = jnp.arange(L)[None, :]
            k_pos = idx + ((cur[:, None] - idx) // L) * L
            k_pos = jnp.where(k_pos < 0, -1_000_000_000, k_pos)
            out = sdpa(q, kread, vread, causal=True, q_offset=cur,
                       window=window, ring_offset=k_pos)
        else:
            if attn_impl == "pallas" and not cache.quantized:
                from repro.kernels import ops as kops

                out = kops.flash_decode(q, kread, vread, kv_len=cur + 1,
                                        q_offset=cur, window=window,
                                        mesh=mesh)
            else:
                out = sdpa(q, kread, vread, causal=True, q_offset=cur,
                           kv_len=cur + 1, window=window)
        new_kv = KVCache(kbuf, vbuf, ringed, k_sc, v_sc)
    out = out.reshape(b, s, h * hd)
    return out @ p["wo"], new_kv


def init_cross_attention(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wo": dense_init(ks[3], d, d, dtype),
    }


def cross_attention_block(p: Params, cfg, x: jnp.ndarray,
                          enc_kv: Tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    """Decoder cross-attention over precomputed encoder K/V."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k, v = enc_kv
    out = sdpa(q, k, v, causal=False)
    return out.reshape(b, s, d) @ p["wo"]


def encode_cross_kv(p: Params, cfg, enc_out: jnp.ndarray):
    b, se, d = enc_out.shape
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    k = (enc_out @ p["wk"]).reshape(b, se, h, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, h, hd)
    return k, v


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_block(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in p:
        act = jax.nn.silu(x @ p["w_gate"]) if cfg.activation == "silu" else jax.nn.gelu(x @ p["w_gate"])
        return (act * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    h = jax.nn.gelu(h) if cfg.activation == "gelu" else jax.nn.silu(h)
    return h @ p["w_down"]
