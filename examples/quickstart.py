"""Quickstart: serve a reduced model through ELIS with ISRTF scheduling.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced qwen2-1.5b, submits a handful of prompts with bursty
(Gamma) arrivals, and prints per-job JCT under the ISRTF scheduler driving
the live JAX engine.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ELISFrontend,
    FrontendConfig,
    Job,
    OraclePredictor,
    SchedulerConfig,
    summarize,
)
from repro.data import GammaArrivals, HashTokenizer
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params


def main():
    cfg = get_config("qwen2-1.5b").reduced()
    print(f"model: {cfg.arch_id} ({cfg.n_layers}L d={cfg.d_model})")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=256, max_output=24, eos_id=-1,
        respect_job_max=True))

    frontend = ELISFrontend(
        FrontendConfig(n_nodes=1,
                       scheduler=SchedulerConfig(policy="isrtf", window=8,
                                                 batch_size=2)),
        OraclePredictor(),
        EngineExecutor({0: engine}),
    )

    tok = HashTokenizer()
    prompts = [
        ("what is the weather forecast", 8),
        ("write a long detailed story about a storm", 24),
        ("yes or no: is it raining", 6),
        ("explain how rain forms step by step", 16),
    ]
    rng = np.random.RandomState(0)
    arrivals = GammaArrivals().rate_scaled(2.0).sample_arrival_times(
        len(prompts), rng)
    for i, ((text, length), t) in enumerate(zip(prompts, arrivals)):
        frontend.submit(Job(job_id=i, prompt=text,
                            prompt_tokens=tok.encode(text),
                            arrival_time=float(t), true_output_len=length))

    done = frontend.run()
    print(f"\n{'job':>3s} {'len':>4s} {'JCT s':>8s} {'queue s':>8s}  prompt")
    for j in sorted(done, key=lambda j: j.job_id):
        print(f"{j.job_id:3d} {j.tokens_generated:4d} {j.jct():8.2f} "
              f"{j.queuing_delay:8.2f}  {j.prompt[:40]}")
    m = summarize(done)
    print(f"\nmean JCT {m['jct_mean']:.2f}s; mean queuing delay "
          f"{m['queuing_delay_mean']:.2f}s; throughput {m['throughput_rps']:.2f} req/s")


if __name__ == "__main__":
    main()
