"""Paper Table 5 / Fig. 5: average JCT per model × RPS × scheduler.

5 models (opt6.7, opt13, lam7, lam13, vic) × RPS multiples {1, 3, 5} ×
{FCFS, ISRTF, SJF-oracle}, batch size 4, 200 prompts, 3 shuffled trials —
the paper's main experiment, on the calibrated discrete-event cluster,
driven through the online ``ElisServer`` request API (``simulate.runner``).
Also reproduces the Fig. 5-right queuing-delay decomposition for the best
case and the ISRTF-vs-FCFS improvement matrix.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.metrics import improvement
from repro.simulate import ExperimentConfig, compare_policies

from benchmarks.common import save_results

#: paper Table 5 (avg JCT seconds) for side-by-side reporting
PAPER_TABLE5 = {
    ("opt13", 1.0): (77.83, 73.57, 20.35),
    ("opt13", 3.0): (116.46, 98.74, 43.63),
    ("opt13", 5.0): (118.13, 118.11, 43.63),
    ("opt6.7", 1.0): (45.08, 50.52, 13.21),
    ("opt6.7", 3.0): (83.42, 72.33, 24.62),
    ("opt6.7", 5.0): (73.93, 74.41, 31.91),
    ("vic", 1.0): (93.42, 73.43, 32.34),
    ("vic", 3.0): (134.96, 118.22, 58.39),
    ("vic", 5.0): (144.23, 131.38, 60.98),
    ("lam13", 1.0): (240.25, 212.60, 70.55),
    ("lam13", 3.0): (350.55, 352.53, 133.11),
    ("lam13", 5.0): (451.59, 377.29, 125.59),
    ("lam7", 1.0): (91.28, 130.71, 37.02),
    ("lam7", 3.0): (229.64, 200.34, 59.37),
    ("lam7", 5.0): (251.66, 234.08, 89.64),
}


def run(quick: bool = False) -> List[Dict]:
    models = ["opt6.7", "lam13"] if quick else ["opt6.7", "opt13", "lam7",
                                                "lam13", "vic"]
    rps_list = [1.0, 3.0] if quick else [1.0, 3.0, 5.0]
    n_req = 100 if quick else 200
    n_trials = 2 if quick else 3
    rows = []
    for model in models:
        for rps in rps_list:
            cfg = ExperimentConfig(model=model, n_requests=n_req,
                                   batch_size=4, rps_multiple=rps, seed=7)
            res = compare_policies(cfg, ("fcfs", "isrtf", "sjf"),
                                   n_trials=n_trials)
            paper = PAPER_TABLE5.get((model, rps))
            row = {
                "model": model,
                "rps_multiple": rps,
                "fcfs_jct": round(res["fcfs"]["jct_mean"], 2),
                "isrtf_jct": round(res["isrtf"]["jct_mean"], 2),
                "sjf_jct": round(res["sjf"]["jct_mean"], 2),
                "isrtf_vs_fcfs_pct": round(improvement(res["fcfs"],
                                                       res["isrtf"]), 2),
                "sjf_vs_fcfs_pct": round(improvement(res["fcfs"],
                                                     res["sjf"]), 2),
                "fcfs_qdelay": round(res["fcfs"]["queuing_delay_mean"], 2),
                "isrtf_qdelay": round(res["isrtf"]["queuing_delay_mean"], 2),
                "ordering_ok": res["sjf"]["jct_mean"]
                <= res["isrtf"]["jct_mean"] * 1.1
                and res["isrtf"]["jct_mean"] <= res["fcfs"]["jct_mean"] * 1.1,
                # lifecycle sanity from the Response-level accounting: no
                # request may end CANCELLED/EXPIRED in the closed-loop runs
                "all_finished": all(res[p]["n_unfinished"] == 0
                                    for p in ("fcfs", "isrtf", "sjf")),
            }
            if paper:
                row["paper_fcfs"], row["paper_isrtf"], row["paper_sjf"] = paper
            rows.append(row)
    save_results("table5_jct", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
