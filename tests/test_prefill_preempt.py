"""Chunked prefill + KV-offload preemption: scheduler debt, the
swap-vs-recompute decision, sim-executor swap accounting, scale-path
trace identity with both features enabled, and the launcher guards
(``--mesh`` parsing, fairness zero-division, ``calibrated_profile``
error surfaces)."""
import numpy as np
import pytest

from repro.core import PREEMPT_POLICIES, Job, PreemptionConfig, SchedulerConfig
from repro.core.metrics import fairness_ratio
from repro.core.scheduler import decide_preempt, prefill_debt
from repro.data.workload import ScaleWorkload
from repro.engine import EngineExecutor
from repro.launch.serve import parse_mesh
from repro.simulate import ExperimentConfig, run_experiment
from repro.simulate.executor import SimExecutor
from repro.simulate.profiles import PROFILES
from repro.simulate.scale import (
    ScaleSimConfig,
    ScaleSimulator,
    run_exact_reference,
)


def _job(i, plen, out=0):
    j = Job(job_id=i, prompt="x", prompt_tokens=list(range(plen)),
            arrival_time=0.0, true_output_len=max(out, 1),
            output_tokens=list(range(100, 100 + max(out, 1))))
    return j


# --------------------------------------------------------------------------- #
# Scheduler: prefill debt + the preempt decision
# --------------------------------------------------------------------------- #


class TestSchedulerCore:
    def test_prefill_debt_off_without_chunking(self):
        j = _job(0, 10)
        j.prefilled_tokens = 0
        assert prefill_debt(SchedulerConfig(), j) == 0.0

    def test_prefill_debt_counts_unprefilled_context(self):
        cfg = SchedulerConfig(prefill_chunk=4)
        j = _job(0, 10)
        assert prefill_debt(cfg, j) == 10.0          # nothing ingested yet
        j.prefilled_tokens = 6
        assert prefill_debt(cfg, j) == 4.0           # mid-chunk cursor
        j.generated = [1, 2, 3]
        assert prefill_debt(cfg, j) == 7.0           # generated adds context
        j.prefilled_tokens = 99
        assert prefill_debt(cfg, j) == 0.0           # clamped, never negative

    def test_decide_preempt_validates_policy(self):
        with pytest.raises(ValueError) as e:
            decide_preempt(PreemptionConfig(policy="nope"), None, 0.0)
        for p in PREEMPT_POLICIES:
            assert p in str(e.value)

    def test_decide_preempt_fixed_policies(self):
        costs = (0.1, 9.0)
        assert decide_preempt(
            PreemptionConfig(policy="recompute"), costs, 5.0) == "recompute"
        assert decide_preempt(
            PreemptionConfig(policy="swap"), costs, 5.0) == "swap"

    def test_decide_preempt_auto_breakeven(self):
        cfg = PreemptionConfig(policy="auto", swap_hold_s_per_token=1e-3)
        # swap 0.1s + hold 0.05s < recompute 0.5s -> swap
        assert decide_preempt(cfg, (0.1, 0.5), 50.0) == "swap"
        # a long predicted remaining makes holding host KV not worth it
        assert decide_preempt(cfg, (0.1, 0.5), 1000.0) == "recompute"
        # no cost estimate (no fit yet / nothing prefetched) -> recompute
        assert decide_preempt(cfg, None, 50.0) == "recompute"

    def test_scale_config_validates_chunk_and_policy(self):
        with pytest.raises(ValueError):
            ScaleSimConfig(prefill_chunk=0).validate()
        with pytest.raises(ValueError):
            ScaleSimConfig(
                preemption=PreemptionConfig(policy="bogus")).validate()


# --------------------------------------------------------------------------- #
# Launcher / metrics guards
# --------------------------------------------------------------------------- #


class TestGuards:
    def test_parse_mesh_accepts_dxm(self):
        assert parse_mesh("2x4") == (2, 4)
        assert parse_mesh("1X1") == (1, 1)

    @pytest.mark.parametrize("bad", ["2x", "x4", "2x3x4", "ax4", "2x4.5",
                                     "0x4", "2x-1", ""])
    def test_parse_mesh_rejects_malformed(self, bad):
        with pytest.raises(ValueError, match="DxM"):
            parse_mesh(bad)

    def test_fairness_ratio_guards_zero_min(self):
        assert fairness_ratio({"a": 2.0, "b": 1.0}) == 2.0
        # a zero-JCT tenant next to a non-zero one is maximal unfairness,
        # not a ZeroDivisionError
        assert fairness_ratio({"a": 2.0, "b": 0.0}) == float("inf")
        assert fairness_ratio({"a": 0.0, "b": 0.0}) == 0.0
        assert fairness_ratio({"a": 1.0}) == 0.0

    def test_calibrated_profile_errors(self):
        ex = EngineExecutor({0: object()})
        with pytest.raises(ValueError, match="no executed windows"):
            ex.calibrated_profile()
        with pytest.raises(ValueError, match="unknown node"):
            ex.calibrated_profile(nodes=[7])


# --------------------------------------------------------------------------- #
# SimExecutor: swap accounting
# --------------------------------------------------------------------------- #


class TestSimExecutorSwap:
    def test_offload_restore_and_recompute_accounting(self):
        ex = SimExecutor(PROFILES["lam13"])
        j = _job(0, 20, out=30)
        r = ex.execute(0, [j], 5, 0.0)
        j.generated.extend(r.tokens[0])
        assert j.prefilled_tokens == 25              # prompt + 5 generated
        costs = ex.preempt_costs(0, j)
        assert costs is not None and costs[0] > 0 and costs[1] > 0
        # swap out: KV survives, restore pays bandwidth not recompute
        assert ex.offload(0, j)
        assert ex.n_swapouts == 1 and ex.swapout_tokens == 25
        assert j.prefilled_tokens == 25
        assert ex.restore(0, j)
        r = ex.execute(0, [j], 5, 1.0)
        j.generated.extend(r.tokens[0])
        assert ex.recompute_prefill_tokens == 0
        assert j.prefilled_tokens == 30              # prompt + 10 generated
        # recompute eviction: cursor resets and the resume is re-charged
        ex.evict(0, j)
        assert j.prefilled_tokens == 0
        assert ex.preempt_costs(0, j) is None        # nothing resident
        ex.execute(0, [j], 5, 2.0)
        assert ex.recompute_prefill_tokens == 30     # prompt + 10 generated

    def test_swap_cost_scales_with_bandwidth(self):
        slow = SimExecutor(PROFILES["lam13"], swap_bandwidth_bytes_s=1e9)
        fast = SimExecutor(PROFILES["lam13"], swap_bandwidth_bytes_s=64e9)
        for ex in (slow, fast):
            j = _job(0, 50, out=10)
            ex.execute(0, [j], 2, 0.0)
            ex.last_costs = ex.preempt_costs(0, j)
        assert slow.last_costs[0] > fast.last_costs[0]
        assert slow.last_costs[1] == fast.last_costs[1]


# --------------------------------------------------------------------------- #
# ExperimentConfig threading
# --------------------------------------------------------------------------- #


def test_experiment_threads_chunk_and_swap():
    cfg = ExperimentConfig(
        model="lam13", policy="isrtf", n_requests=40, batch_size=3,
        rps_multiple=2.0, predictor="oracle", seed=3, prefill_chunk=64,
        preemption=PreemptionConfig(policy="auto", margin=5.0))
    m = run_experiment(cfg)
    assert m["n_finished"] == 40
    for k in ("swapouts", "swapins", "recompute_prefill_tokens"):
        assert k in m


# --------------------------------------------------------------------------- #
# Scale fast path: trace-identical with both features enabled
# --------------------------------------------------------------------------- #


def _mixed_workload(n, seed):
    r = np.random.RandomState(seed)
    arrival = np.sort(r.uniform(0, 20, n))
    plen = np.where(r.rand(n) < 0.4, r.randint(200, 800, n),
                    r.randint(8, 40, n))
    return ScaleWorkload(
        arrival=arrival, length=r.randint(5, 120, n).astype(np.int64),
        prompt_len=plen.astype(np.int64),
        tenant_id=np.zeros(n, dtype=np.int32),
        priority_class=np.where(r.rand(n) < 0.2, 1, 0).astype(np.int16),
        deadline=np.full(n, np.inf))


def _assert_trace_identical(cfg, w):
    ex = run_exact_reference(cfg, w)
    sc = ScaleSimulator(cfg).run(w)
    for f in ("state", "n_preemptions", "n_iterations", "finished_order"):
        assert np.array_equal(getattr(ex, f), getattr(sc, f)), f
    for f in ("finish", "first_token", "queuing_delay"):
        a = np.nan_to_num(getattr(ex, f), nan=-1.0)
        b = np.nan_to_num(getattr(sc, f), nan=-1.0)
        assert np.array_equal(a, b), f
    assert (ex.n_swapouts, ex.n_swapins, ex.recompute_prefill_tokens) == \
           (sc.n_swapouts, sc.n_swapins, sc.recompute_prefill_tokens)
    return sc


class TestScaleTraceIdentity:
    def test_chunked_prefill_trace_identical(self):
        w = _mixed_workload(120, 0)
        cfg = ScaleSimConfig(model="vic", n_nodes=2, batch_size=3, window=40,
                             seed=0, prefill_chunk=48)
        _assert_trace_identical(cfg, w)

    def test_swap_policy_trace_identical(self):
        w = _mixed_workload(120, 1)
        cfg = ScaleSimConfig(
            model="vic", n_nodes=2, batch_size=3, window=40, seed=0,
            aging_rate=2.0,
            preemption=PreemptionConfig(policy="swap", margin=5.0))
        sc = _assert_trace_identical(cfg, w)
        assert sc.n_swapouts > 0                     # the tier actually fired

    def test_both_features_auto_trace_identical(self):
        w = _mixed_workload(120, 2)
        cfg = ScaleSimConfig(
            model="vic", n_nodes=2, batch_size=3, window=40, seed=0,
            prefill_chunk=32,
            preemption=PreemptionConfig(policy="auto", margin=5.0))
        _assert_trace_identical(cfg, w)
