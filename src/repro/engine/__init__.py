from repro.engine.engine import (
    EngineConfig,
    EngineExecutor,
    InferenceEngine,
    make_tp_pods,
)
from repro.engine.sampler import SamplerConfig, sample

__all__ = [
    "EngineConfig",
    "EngineExecutor",
    "InferenceEngine",
    "SamplerConfig",
    "make_tp_pods",
    "sample",
]
