from repro.data.arrivals import (
    FABRIX_ALPHA,
    FABRIX_SCALE,
    GammaArrivals,
    PoissonArrivals,
    exponential_loglik,
    fit_gamma,
    gamma_loglik,
)
from repro.data.dataset import (
    WINDOW,
    batch_bucket,
    batch_iterator,
    build_step_samples,
    iqr_filter,
    make_predictor_dataset,
    n_shape_buckets,
    pad_batch,
    seq_bucket,
    split_622,
)
from repro.data.tokenizer import HashTokenizer
from repro.data.workload import (
    Request,
    WorkloadGenerator,
    bursty_arrival_times,
    similarity_probe_sets,
)

__all__ = [
    "FABRIX_ALPHA",
    "FABRIX_SCALE",
    "GammaArrivals",
    "HashTokenizer",
    "PoissonArrivals",
    "Request",
    "WINDOW",
    "WorkloadGenerator",
    "batch_bucket",
    "batch_iterator",
    "build_step_samples",
    "bursty_arrival_times",
    "exponential_loglik",
    "fit_gamma",
    "gamma_loglik",
    "iqr_filter",
    "make_predictor_dataset",
    "n_shape_buckets",
    "pad_batch",
    "seq_bucket",
    "similarity_probe_sets",
    "split_622",
]
