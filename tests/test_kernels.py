"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_reference_sequential


def rand(key, shape, dtype=jnp.float32):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("s,h,kh,d", [
    (128, 4, 4, 64),     # MHA
    (256, 4, 2, 64),     # GQA 2:1
    (256, 8, 1, 32),     # MQA
    (512, 2, 2, 128),    # long-seq, MXU-width head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kh, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (2, s, h, d), dtype)
    k = rand(ks[1], (2, s, kh, d), dtype)
    v = rand(ks[2], (2, s, kh, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 256, 4, 32))
    k = rand(ks[1], (1, 256, 2, 32))
    v = rand(ks[2], (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("L,kh,d", [(256, 2, 64), (512, 4, 32), (128, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(L, kh, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, H = 3, 4
    q = rand(ks[0], (B, 1, H, d), dtype)
    k = rand(ks[1], (B, L, kh, d), dtype)
    v = rand(ks[2], (B, L, kh, d), dtype)
    kv_len = jnp.array([1, L // 2, L], jnp.int32)  # heterogeneous depths
    q_off = kv_len - 1
    out = ops.flash_decode(q, k, v, kv_len=kv_len, q_offset=q_off)
    want = ref.reference_decode_attention(q, k, v, kv_len=kv_len,
                                          q_offset=q_off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_decode_window():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, L, d = 2, 2, 256, 32
    q = rand(ks[0], (B, 1, H, d))
    k = rand(ks[1], (B, L, H, d))
    v = rand(ks[2], (B, L, H, d))
    kv_len = jnp.array([200, 256])
    out = ops.flash_decode(q, k, v, kv_len=kv_len, q_offset=kv_len - 1,
                           window=64)
    want = ref.reference_decode_attention(q, k, v, kv_len=kv_len,
                                          q_offset=kv_len - 1, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (128, 2, 16, 8, 32),
    (256, 3, 32, 16, 64),
    (64, 1, 64, 128, 64),   # mamba2-130m-like head
])
def test_ssd_scan_sweep(s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    B = 2
    x = rand(ks[0], (B, s, h, p))
    a = -jnp.abs(rand(ks[1], (B, s, h))) * 0.1
    Bm = rand(ks[2], (B, s, h, n))
    Cm = rand(ks[3], (B, s, h, n))
    y, fs = ops.ssd_scan(x, a, Bm, Cm, chunk=chunk)
    y_ref, fs_ref = ssd_reference_sequential(x, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref), atol=1e-4)


def test_ssd_scan_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, s, h, p, n = 1, 128, 2, 8, 4
    x = rand(ks[0], (B, s, h, p))
    a = -jnp.abs(rand(ks[1], (B, s, h))) * 0.05
    Bm = rand(ks[2], (B, s, h, n))
    Cm = rand(ks[3], (B, s, h, n))
    y32, f32_ = ops.ssd_scan(x, a, Bm, Cm, chunk=32)
    y64, f64_ = ops.ssd_scan(x, a, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f32_), np.asarray(f64_), atol=1e-4)
