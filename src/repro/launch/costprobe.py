import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline cost probes: exact HLO costs despite rolled layer scans.

XLA's ``cost_analysis`` counts a while-loop body once, so the production
lowering (scan-stacked layers) under-reports FLOPs/bytes/collectives by
~n_layers.  We lower *probe variants* — same input shapes, reduced layer
counts, scans fully unrolled — and extrapolate the affine cost model:

  dense/moe/vlm/ssm : cost(L) = a + L·b             probes L ∈ {2, 4}
  audio (enc-dec)   : cost(k) = a + k·b (enc=dec=k) probes k ∈ {2, 4}
  hybrid (zamba2)   : cost = a + G·g + T·t          probes L ∈ {12, 15, 24}
                      (G groups of [6 mamba + shared attn], T tail mamba)

The SSD chunk recurrence is fully vectorised (no scan), so probe costs are
exact per layer.  Corrected totals are written to experiments/costmodel/.
Approximation notes: zamba2's shared attention is SWA(4096) so per-group
cost is ~shape-independent of depth; extrapolation is exact for everything
else because layers are homogeneous.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, List

from repro.configs import get_config, list_archs
from repro.launch.shapes import SHAPES, supported
from repro.models.scanning import unrolled

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "costmodel")

KEYS = ("flops", "bytes_accessed", "collective_bytes")


def _extract(rec: Dict) -> Dict[str, float]:
    return {
        "flops": rec["flops"],
        "bytes_accessed": rec["bytes_accessed"],
        "collective_bytes": rec["collectives"]["total"],
    }


def _axpy(a: Dict, b: Dict, sa=1.0, sb=1.0) -> Dict:
    return {k: sa * a[k] + sb * b[k] for k in KEYS}


def _probe_cfgs(cfg) -> List:
    r = dataclasses.replace
    if cfg.family == "audio":
        return [
            r(cfg, n_layers=2, encoder=r(cfg.encoder, n_layers=2)),
            r(cfg, n_layers=4, encoder=r(cfg.encoder, n_layers=4)),
        ]
    if cfg.family == "hybrid":
        return [r(cfg, n_layers=12), r(cfg, n_layers=15), r(cfg, n_layers=24)]
    return [r(cfg, n_layers=2), r(cfg, n_layers=4)]


def _extrapolate(cfg, costs: List[Dict]) -> Dict[str, float]:
    if cfg.family == "audio":
        c2, c4 = costs
        per = _axpy(c4, c2, 0.5, -0.5)  # per (enc+dec) layer pair
        return _axpy(c2, per, 1.0, cfg.n_layers - 2)
    if cfg.family == "hybrid":
        c12, c15, c24 = costs
        # L=12 -> 2 groups, L=24 -> 4 groups: per-group = (c24 - c12) / 2
        g = _axpy(c24, c12, 0.5, -0.5)             # per group (6 mamba + attn)
        t = _axpy(c15, c12, 1 / 3.0, -1 / 3.0)     # per tail mamba layer
        a = _axpy(c12, g, 1.0, -2.0)
        every = cfg.hybrid.attn_every
        n_groups = cfg.n_layers // every
        n_tail = cfg.n_layers - n_groups * every
        out = _axpy(a, g, 1.0, float(n_groups))
        return _axpy(out, t, 1.0, float(n_tail))
    c2, c4 = costs
    per = _axpy(c4, c2, 0.5, -0.5)
    return _axpy(c2, per, 1.0, cfg.n_layers - 2)


def probe(arch: str, shape_name: str, *, moe_scheme: str = "tensor",
          remat: bool = True, tag: str = "", **perf_knobs) -> Dict:
    """``perf_knobs`` forward to lower_one (kv_dtype, kv_shard,
    params_data_sharded, mesh_shape) so §Perf variants get corrected costs."""
    from repro.launch.dryrun import lower_one

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supported(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped"}
    t0 = time.time()
    costs = []
    probes_meta = []
    with unrolled():
        for pc in _probe_cfgs(cfg):
            rec = lower_one(arch, shape_name, False, moe_scheme=moe_scheme,
                            remat=remat, cfg_override=pc, save_record=False,
                            **perf_knobs)
            if rec["status"] != "ok":
                return {"arch": arch, "shape": shape_name, "status": "error",
                        "error": rec.get("error")}
            costs.append(_extract(rec))
            probes_meta.append({"n_layers": pc.n_layers, **costs[-1]})
    corrected = _extrapolate(cfg, costs)
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "single",
        "moe_scheme": moe_scheme,
        "tag": tag,
        "status": "ok",
        "perf_knobs": {k: str(v) for k, v in perf_knobs.items()},
        "probe_seconds": round(time.time() - t0, 1),
        "probes": probes_meta,
        "corrected": corrected,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(OUT_DIR, f"{arch}_{shape_name}_single{suffix}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-scheme", default="tensor")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    archs = list(list_archs()) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    for arch in archs:
        for shape in shapes:
            try:
                rec = probe(arch, shape, moe_scheme=args.moe_scheme,
                            tag=args.tag)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                print(f"FAIL {arch} x {shape}: {e!r}")
                continue
            if rec["status"] == "ok":
                c = rec["corrected"]
                print(f"OK   {arch} x {shape}: flops={c['flops']:.3e} "
                      f"bytes={c['bytes_accessed']:.3e} "
                      f"coll={c['collective_bytes']:.3e} "
                      f"({rec['probe_seconds']}s)")
            else:
                print(f"{rec['status'].upper()} {arch} x {shape}")


if __name__ == "__main__":
    main()
