"""ELIS frontend (Algorithm 1) against a scripted executor."""
import json
import os
import shutil
import subprocess
import sys
import tarfile
from typing import List, Sequence

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ELISFrontend,
    ExecResult,
    FrontendConfig,
    Job,
    JobState,
    OraclePredictor,
    PreemptionConfig,
    SchedulerConfig,
)
from repro.core.load_balancer import GlobalState, LeastEtaPlacement


class ScriptedExecutor:
    """Deterministic executor: every window takes 1s, emits token id 7."""

    def __init__(self):
        self.calls = []
        self.evictions = []

    def execute(self, node, jobs: Sequence[Job], window, now) -> ExecResult:
        self.calls.append((now, node, [j.job_id for j in jobs]))
        toks, fin = [], []
        for j in jobs:
            n = min(window, j.true_output_len - j.tokens_generated)
            toks.append([7] * n)
            fin.append(j.tokens_generated + n >= j.true_output_len)
        return ExecResult(1.0, toks, fin)

    def evict(self, node, job):
        self.evictions.append(job.job_id)


def mk_jobs(lens, arrivals=None):
    arrivals = arrivals or [0.0] * len(lens)
    return [
        Job(job_id=i, prompt=f"p{i}", prompt_tokens=[1], arrival_time=a,
            true_output_len=l)
        for i, (l, a) in enumerate(zip(lens, arrivals))
    ]


def run(policy, lens, arrivals=None, batch=2, nodes=1, preempt=True):
    fe = ELISFrontend(
        FrontendConfig(
            n_nodes=nodes,
            scheduler=SchedulerConfig(policy=policy, window=50,
                                      batch_size=batch),
            preemption=PreemptionConfig(enabled=preempt, margin=10,
                                        max_fraction=1.0),
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        ScriptedExecutor(),
    )
    jobs = mk_jobs(lens, arrivals)
    for j in jobs:
        fe.submit(j)
    done = fe.run()
    return {j.job_id: j for j in done}, fe


def test_all_jobs_finish_exact_lengths():
    done, _ = run("fcfs", [120, 49, 50, 51])
    assert len(done) == 4
    for j in done.values():
        assert j.tokens_generated == j.true_output_len
        assert j.finished and j.finish_time is not None


def test_isrtf_runs_short_job_first():
    # batch=1: strict serialization; ISRTF must pick the short job
    done, fe = run("isrtf", [500, 40], batch=1)
    assert done[1].finish_time < done[0].finish_time


def test_fcfs_head_of_line_blocking():
    # FCFS with batch=1: the long job 0 blocks the short job 1
    done, _ = run("fcfs", [500, 40], batch=1, preempt=False)
    assert done[1].finish_time > done[0].finish_time - 1e-9


def test_isrtf_beats_fcfs_mean_jct_here():
    lens = [400, 30, 30, 30, 30, 30]
    d_f, _ = run("fcfs", lens, batch=1, preempt=False)
    d_i, _ = run("isrtf", lens, batch=1)
    mean = lambda d: sum(j.jct() for j in d.values()) / len(d)
    assert mean(d_i) < mean(d_f)


def test_window_iterations_counted():
    done, _ = run("fcfs", [120])
    assert done[0].n_iterations == 3  # 50 + 50 + 20


def test_preemption_happens_and_is_counted():
    # long job running alone; a very short job arrives -> displaces it
    done, fe = run("isrtf", [1000, 10], arrivals=[0.0, 1.5], batch=1)
    assert done[0].n_preemptions >= 1
    assert 0 in fe.executor.evictions
    assert done[1].finish_time < done[0].finish_time


def test_no_preemption_when_disabled():
    done, fe = run("fcfs", [1000, 10], arrivals=[0.0, 1.5], batch=1,
                   preempt=False)
    assert done[0].n_preemptions == 0
    assert fe.executor.evictions == [] or set(fe.executor.evictions) <= {0, 1}


def test_load_balancer_spreads_jobs():
    done, fe = run("fcfs", [100] * 6, nodes=3)
    nodes = {j.node for j in done.values()}
    assert nodes == {0, 1, 2}


def test_queuing_delay_accounting():
    done, _ = run("fcfs", [100, 100, 100], batch=1, preempt=False)
    # with a 1s/window scripted executor, later jobs accrue queuing delay
    delays = [done[i].queuing_delay for i in range(3)]
    assert delays[0] < delays[1] < delays[2]
    for j in done.values():
        assert j.queuing_delay <= j.jct() + 1e-9


# --------------------------------------------------------------------------- #
# Cluster scheduling: placement, rebalancing, accounting invariants
# --------------------------------------------------------------------------- #


def cluster_frontend(nodes, placement="least_jobs", rebalance=False,
                     threshold=50.0, policy="isrtf", batch=2,
                     node_token_cost=None):
    return ELISFrontend(
        FrontendConfig(
            n_nodes=nodes,
            scheduler=SchedulerConfig(policy=policy, window=50,
                                      batch_size=batch),
            preemption=PreemptionConfig(enabled=True, margin=10,
                                        max_fraction=1.0),
            placement=placement,
            rebalance=rebalance,
            rebalance_threshold=threshold,
            node_token_cost=node_token_cost,
        ),
        OraclePredictor() if policy in ("sjf", "isrtf") else None,
        ScriptedExecutor(),
    )


@given(
    lens=st.lists(st.integers(1, 1000), min_size=1, max_size=30),
    nodes=st.integers(2, 5),
)
@settings(max_examples=50, deadline=None)
def test_least_predicted_work_imbalance_bound(lens, nodes):
    """Greedy length-weighted placement with a perfect oracle: after every
    batch of simultaneous arrivals, no node exceeds another's predicted
    work by more than the largest single job."""
    fe = cluster_frontend(nodes, placement="least_predicted_work")
    for j in mk_jobs(lens):
        fe.submit(j)
    for _ in lens:  # arrivals sort before any node_free at equal t
        fe.step()
    work = fe.state.predicted_work
    assert sum(fe.state.active_jobs.values()) == len(lens)
    assert max(work.values()) - min(work.values()) <= max(lens) + 1e-9


@given(
    lens=st.lists(st.integers(1, 600), min_size=4, max_size=16),
    nodes=st.integers(2, 3),
    threshold=st.integers(20, 200),
)
@settings(max_examples=30, deadline=None)
def test_migration_preserves_disjoint_job_sets(lens, nodes, threshold):
    """Rebalancing never moves a RUNNING job and never leaves a job on two
    nodes: after every step each live job appears in exactly one queue, on
    the node its record claims."""
    fe = cluster_frontend(nodes, placement="least_predicted_work",
                          rebalance=True, threshold=float(threshold))
    arrivals = [0.7 * (i % 5) for i in range(len(lens))]
    for j in mk_jobs(lens, arrivals):
        fe.submit(j)
    was_running = set()
    while fe.pending():
        for ev in fe.step():
            if ev.kind == "migrated":
                # the rebalancer only reads waiting queues, so anything
                # that entered this step RUNNING can never be migrated
                # (it may be dispatched and finish AFTER the migration,
                # within the same node_free step)
                assert ev.job_id not in was_running, \
                    f"running job {ev.job_id} was migrated"
        was_running = {j.job_id for node in range(nodes)
                       for j in fe.running[node]}
        seen = {}
        for node in range(nodes):
            for j in fe.running[node] + fe.waiting[node]:
                assert j.job_id not in seen, \
                    f"job {j.job_id} on nodes {seen[j.job_id]} and {node}"
                seen[j.job_id] = node
                assert j.node == node
    assert len(fe.finished) == len(lens)
    for j in fe.finished:
        assert j.tokens_generated == j.true_output_len
    fe.state.assert_drained()


def test_rebalancing_steals_from_overloaded_node():
    """A node that drains early steals queued work from its swamped peer
    (and the stolen jobs are the ones ISRTF would run next)."""
    fe = cluster_frontend(2, placement="least_jobs", rebalance=True,
                          threshold=100.0, batch=1)
    # t=0: a long job to node 0, a tiny one to node 1; while both execute,
    # three mediums arrive and least_jobs stacks two on node 0
    lens = [1000, 10, 300, 300, 300]
    arrivals = [0.0, 0.0, 1.5, 1.5, 1.5]
    for j in mk_jobs(lens, arrivals):
        fe.submit(j)
    done = fe.run()
    assert len(done) == 5
    assert fe.migrations >= 1
    assert sum(j.n_migrations for j in done) == fe.migrations
    fe.state.assert_drained()


def test_global_state_returns_to_zero_after_cancel_and_expiry():
    """Satellite bugfix: a job cancelled or expired while still queued
    (assigned but never dispatched) must retract its predicted-work
    contribution, not just its job count."""
    fe = cluster_frontend(2, placement="least_predicted_work", batch=1)
    jobs = mk_jobs([400, 400, 200, 200, 150])
    jobs[3].deadline = 0.5      # expires before it can ever run
    for j in jobs:
        fe.submit(j)
    fe.run_until(0.1)
    assert fe.cancel(4)         # still waiting: terminates immediately
    done = fe.run()
    states = {j.job_id: j.state for j in fe.terminated}
    assert states[3] is JobState.EXPIRED
    assert states[4] is JobState.CANCELLED
    assert len(done) == 3
    fe.state.assert_drained()
    assert all(w == 0.0 for w in fe.state.predicted_work.values())


def test_least_eta_prefers_fast_node():
    """With per-node token costs, least_eta routes to the pod that will
    finish the job sooner, not the one with fewer jobs."""
    state = GlobalState(2)
    placement = LeastEtaPlacement({0: 1.0, 1: 0.1})
    job = mk_jobs([100])[0]
    assert placement.select(state, job, estimate=100.0, now=0.0) == 1
    # pile predicted work on the fast node until the slow one wins
    state.add_job(1, job_id=99, work=2000.0)
    assert placement.select(state, job, estimate=100.0, now=0.0) == 0


def test_busy_until_is_live_and_monotone():
    """Satellite bugfix: busy_until (dead since seed) now tracks each
    window's horizon and is asserted monotone per node."""
    fe = cluster_frontend(1, placement="least_eta", batch=2,
                          node_token_cost={0: 0.01})
    for j in mk_jobs([120, 80]):
        fe.submit(j)
    horizons = []
    while fe.pending():
        fe.step()
        horizons.append(fe.state.busy_until[0])
    assert horizons[-1] > 0.0
    assert horizons == sorted(horizons)
    with pytest.raises(AssertionError):
        fe.state.note_busy(0, horizons[-1] - 1.0)


# --------------------------------------------------------------------------- #
# Trace identity: least_jobs reproduces the pre-cluster-layer balancer
# --------------------------------------------------------------------------- #

#: last commit before the cluster-scheduling layer (PR 2)
PRE_PR_SHA = "726cdb4"
#: last commit before the distribution-aware predictor API (PR 5)
PRE_PR5_SHA = "9e4b2da"
#: last commit before the learning-to-rank subsystem (PR 10)
PRE_PR10_SHA = "a4aaa01"

PROBE = """
import json
from repro.simulate import ExperimentConfig, run_experiment
cfg = ExperimentConfig(model="vic", policy="isrtf", predictor="noisy_oracle",
                       n_requests=50, n_nodes=3, batch_size=4,
                       rps_multiple=1.5, seed=0)
print(json.dumps(run_experiment(cfg), sort_keys=True))
"""

#: exercises the new predict() path harder: work-aware placement (the
#: arrival-time prediction), rebalancing, and bursty arrivals — with
#: calibration off and risk_quantile=None it must replay the old
#: init/iter scoring draw-for-draw
PROBE_PREDICT = """
import json
from repro.simulate import ExperimentConfig, run_experiment
cfg = ExperimentConfig(model="vic", policy="isrtf", predictor="noisy_oracle",
                       n_requests=40, n_nodes=2, batch_size=4,
                       rps_multiple=1.3, seed=3,
                       placement="least_predicted_work", rebalance=True,
                       arrivals="bursty", burst_size=12)
print(json.dumps(run_experiment(cfg), sort_keys=True))
"""


#: PR 10 pin: with ranking disabled (the defaults — rank_by="magnitude",
#: PredictorConfig.ranking=None) the two-head refactor of the predictor and
#: the rank_by branch in score_jobs must be invisible; preemption pressure
#: (tight batch, bursty arrivals) exercises the swap-pool-adjacent engine
#: paths with swap_pool_tokens unset
PROBE_RANK_OFF = """
import json
from repro.simulate import ExperimentConfig, run_experiment
cfg = ExperimentConfig(model="vic", policy="isrtf", predictor="noisy_oracle",
                       n_requests=40, n_nodes=2, batch_size=3,
                       rps_multiple=1.6, seed=5,
                       placement="least_predicted_work",
                       arrivals="bursty", burst_size=16)
print(json.dumps(run_experiment(cfg), sort_keys=True))
"""


def _old_build_metrics(tmp_path, sha, probe):
    """Run ``probe`` against a git-archive checkout of ``sha``; skips when
    the sha is unavailable (shallow checkout) or git is missing."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if shutil.which("git") is None:
        pytest.skip("git unavailable")
    ar = subprocess.run(
        ["git", "-C", repo, "archive", sha, "src"],
        capture_output=True)
    if ar.returncode != 0:
        pytest.skip(f"pre-PR sha {sha} unavailable "
                    f"(shallow checkout?): {ar.stderr.decode()[:200]}")
    old = tmp_path / "old"
    old.mkdir()
    tar = tmp_path / "old.tar"
    tar.write_bytes(ar.stdout)
    with tarfile.open(tar) as tf:
        tf.extractall(old)

    env = dict(os.environ, PYTHONPATH=str(old / "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", probe], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout)


def test_least_jobs_trace_identical_to_pre_pr(tmp_path):
    """Default placement must reproduce the pre-PR greedy balancer
    bit-identically (NoisyOraclePredictor draws RNG per prediction in
    scoring order, so any divergence in placement, scoring order, or event
    ordering shows up immediately in every aggregate)."""
    old_metrics = _old_build_metrics(tmp_path, PRE_PR_SHA, PROBE)

    from repro.simulate import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(model="vic", policy="isrtf",
                           predictor="noisy_oracle", n_requests=50,
                           n_nodes=3, batch_size=4, rps_multiple=1.5, seed=0)
    new_metrics = run_experiment(cfg)
    # the old build predates the migration counter; every metric it knows
    # about must match bit-for-bit
    for k, v in old_metrics.items():
        assert new_metrics[k] == v, (k, v, new_metrics[k])


def test_predict_api_trace_identical_to_pre_pr5(tmp_path):
    """The distribution-aware predict() path (PR 5), with calibration off
    and risk_quantile=None, must reproduce the scalar-era scheduler
    bit-identically — including the arrival-estimate draws consumed by
    work-aware placement and the rebalancer."""
    old_metrics = _old_build_metrics(tmp_path, PRE_PR5_SHA, PROBE_PREDICT)

    from repro.simulate import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(model="vic", policy="isrtf",
                           predictor="noisy_oracle", n_requests=40,
                           n_nodes=2, batch_size=4, rps_multiple=1.3, seed=3,
                           placement="least_predicted_work", rebalance=True,
                           arrivals="bursty", burst_size=12)
    new_metrics = run_experiment(cfg)
    for k, v in old_metrics.items():
        assert new_metrics[k] == v, (k, v, new_metrics[k])


def test_rank_subsystem_off_trace_identical_to_pre_pr10(tmp_path):
    """With the learning-to-rank subsystem disabled (the defaults), the
    per-job JCT trace must be bit-identical to the pre-PR-10 build: the
    rank_by branch, the LengthPrediction.rank_score field, and the two-head
    predictor plumbing may not perturb a single draw or comparison."""
    old_metrics = _old_build_metrics(tmp_path, PRE_PR10_SHA, PROBE_RANK_OFF)

    from repro.simulate import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(model="vic", policy="isrtf",
                           predictor="noisy_oracle", n_requests=40,
                           n_nodes=2, batch_size=3, rps_multiple=1.6, seed=5,
                           placement="least_predicted_work",
                           arrivals="bursty", burst_size=16)
    new_metrics = run_experiment(cfg)
    for k, v in old_metrics.items():
        assert new_metrics[k] == v, (k, v, new_metrics[k])
