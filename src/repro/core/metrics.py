"""JCT / queuing-delay / throughput metrics (paper §6 evaluation)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.job import Job


def summarize(jobs: Sequence[Job]) -> Dict[str, float]:
    """Aggregate JCT/queuing/throughput metrics over finished jobs (or
    Response records — anything with the same timing surface)."""
    if not jobs:
        # zero requests finished (all cancelled/expired): report an empty
        # but well-formed summary rather than crashing the caller
        keys = ("jct_mean", "jct_p50", "jct_p99", "jct_min", "jct_max",
                "queuing_delay_mean", "throughput_rps", "makespan",
                "ttft_mean")
        out: Dict[str, float] = {k: 0.0 for k in keys}
        out["n"] = 0
        out["preemptions"] = 0
        return out
    jcts = np.array([j.jct() for j in jobs])
    qd = np.array([j.queuing_delay for j in jobs])
    makespan = max(j.finish_time for j in jobs) - min(
        j.arrival_time for j in jobs
    )
    return {
        "n": len(jobs),
        "jct_mean": float(jcts.mean()),
        "jct_p50": float(np.percentile(jcts, 50)),
        "jct_p99": float(np.percentile(jcts, 99)),
        "jct_min": float(jcts.min()),
        "jct_max": float(jcts.max()),
        "queuing_delay_mean": float(qd.mean()),
        "throughput_rps": len(jobs) / max(makespan, 1e-9),
        "makespan": float(makespan),
        "preemptions": int(sum(j.n_preemptions for j in jobs)),
        "ttft_mean": float(
            np.mean([
                j.first_token_time - j.arrival_time
                for j in jobs if j.first_token_time is not None
            ])
        ),
    }


def improvement(base: Dict[str, float], new: Dict[str, float],
                key: str = "jct_mean") -> float:
    """Percent reduction of ``key`` relative to ``base`` (paper Fig. 6)."""
    return 100.0 * (base[key] - new[key]) / base[key]
