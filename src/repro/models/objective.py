"""Training objective: next-token cross-entropy (+ MoE aux loss)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Mean masked token-level CE.  labels < 0 are also ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = mask & (labels >= 0)
    denom = jnp.maximum(jnp.sum(mask), 1)
    return jnp.sum(jnp.where(mask, nll, 0.0)) / denom


def loss_fn(params, cfg, batch: Dict, *, attn_impl: str = "xla",
            moe_impl: str = "dense", remat: bool = False) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = T.forward(params, cfg, batch, attn_impl=attn_impl,
                            moe_impl=moe_impl, remat=remat)
    labels = batch["labels"]
    # VLM: stub patch positions carry no labels; logits cover [patches|text]
    if logits.shape[1] != labels.shape[1]:
        extra = logits.shape[1] - labels.shape[1]
        pad = jnp.full(labels.shape[:1] + (extra,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(labels, dtype=bool)
    elif mask.shape[1] != labels.shape[1]:
        extra = labels.shape[1] - mask.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros(mask.shape[:1] + (extra,), bool), mask], axis=1
        )
    ce = cross_entropy(logits, labels, mask)
    total = ce + cfg.moe.router_aux_weight * aux if cfg.moe.enabled else ce
    return total, {"ce": ce, "aux": aux}
