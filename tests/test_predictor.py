"""Response-length predictor (paper §3.2/3.3/4.2, Table 2, Fig. 2b).

The heavier "does training reach good R²" checks live in
benchmarks/table2_predictor.py; here we verify the mechanisms cheaply.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BGEPredictor, Job, PredictorConfig
from repro.core.predictor import OraclePredictor
from repro.data import make_predictor_dataset
from repro.models.encoder import EncoderArchConfig, encode, init_encoder


@pytest.fixture(scope="module")
def tiny_cfg():
    return PredictorConfig(
        encoder=EncoderArchConfig(d_model=64, n_heads=2, n_layers=2, d_ff=128,
                                  max_len=128),
        n_fc_layers=8,       # paper: eight FC layers
        fc_hidden=128,
        max_len=128,
        lr=3e-4,             # scratch encoder (not pretrained) — see DESIGN §7
    )


def test_head_has_eight_layers(tiny_cfg):
    p = BGEPredictor(tiny_cfg)
    assert len(p.params["head"]["layers"]) == 8


def test_untrained_predictions_positive(tiny_cfg):
    p = BGEPredictor(tiny_cfg)
    out = p.predict_tokens([[1, 2, 3], [4, 5, 6, 7]])
    assert out.shape == (2,)
    assert (out >= 1).all()


def test_training_improves_mae(tiny_cfg):
    tr, va, te = make_predictor_dataset(400, seed=0, max_len=128, max_steps=4)
    p = BGEPredictor(tiny_cfg, seed=0)
    before = p.evaluate(te[:200])
    p.fit(tr, num_steps=250, batch_size=32)
    after = p.evaluate(te[:200])
    assert after["mae"] < before["mae"]
    assert after["r2"] > before["r2"]


def test_frozen_encoder_mode(tiny_cfg):
    import dataclasses

    cfg = dataclasses.replace(tiny_cfg, freeze_encoder=True)
    p = BGEPredictor(cfg, seed=0)
    enc_before = jax.tree_util.tree_leaves(p.params["encoder"])[0].copy()
    tr, _, _ = make_predictor_dataset(100, seed=1, max_len=128, max_steps=2)
    p.fit(tr[:64], num_steps=10, batch_size=16)
    enc_after = jax.tree_util.tree_leaves(p.params["encoder"])[0]
    np.testing.assert_array_equal(np.asarray(enc_before),
                                  np.asarray(enc_after))


def test_bucketed_traces_bounded_and_dispatch_counted(tiny_cfg):
    """Growing the pool one job at a time must NOT retrace per pool size:
    batch shapes are bucketed to powers of two, so 1..8 jobs compile at
    most 4 batch buckets (x1 sequence bucket here)."""
    p = BGEPredictor(tiny_cfg)
    base = p.num_traces
    for b in range(1, 9):
        out = p.predict_tokens([[1, 2, 3]] * b)
        assert out.shape == (b,)             # padding rows sliced off
    assert p.num_dispatches == 8
    assert p.num_traces - base == 4          # buckets {1, 2, 4, 8}
    # repeating any pool size hits the jit cache — no new traces
    p.predict_tokens([[1, 2, 3]] * 5)
    assert p.num_traces - base == 4


def test_seq_bucket_ladder_controls_retraces(tiny_cfg):
    p = BGEPredictor(tiny_cfg)
    base = p.num_traces
    p.predict_tokens([[1] * 5])              # seq bucket 32
    p.predict_tokens([[1] * 30])             # still 32
    assert p.num_traces - base == 1
    p.predict_tokens([[1] * 40])             # seq bucket 64
    assert p.num_traces - base == 2
    p.predict_tokens([[1] * 999])            # clipped to max_len bucket (128)
    assert p.num_traces - base == 3


def test_bucketed_padding_is_inert(tiny_cfg):
    """A row's prediction must not depend on the bucket it was computed in
    (padding rows/columns are fully masked)."""
    p = BGEPredictor(tiny_cfg)
    rows = [[1, 2, 3], [4, 5, 6, 7, 8], [9] * 40]
    batched = p.predict_tokens(rows)
    singles = np.array([p.predict_tokens([r])[0] for r in rows])
    np.testing.assert_allclose(batched, singles, rtol=1e-4)


def test_iterative_input_includes_partial_output(tiny_cfg):
    p = BGEPredictor(tiny_cfg)
    j = Job(job_id=0, prompt="x", prompt_tokens=[10, 11], arrival_time=0.0)
    base = p._job_input(j)
    j.generated = [20, 21, 22]
    longer = p._job_input(j)
    assert len(longer) == len(base) + 3
    assert longer[: len(base)] == base


def test_evaluate_pads_per_chunk_not_whole_list(tiny_cfg, monkeypatch):
    """evaluate must never materialise one (N, max_len) array for the whole
    sample list: padding happens per 256-row chunk (batch dim bucketed, so
    trailing chunks stay on the compile ladder)."""
    import repro.core.predictor as P

    tr, _, te = make_predictor_dataset(260, seed=2, max_len=128, max_steps=3)
    samples = (tr + te)[:300]
    p = BGEPredictor(tiny_cfg)

    seen = []
    orig = p._apply

    def spying_apply(params, toks, mask):
        seen.append(toks.shape)
        return orig(params, toks, mask)

    monkeypatch.setattr(p, "_apply", spying_apply)
    m = p.evaluate(samples)
    assert all(shape[0] <= 256 for shape in seen), seen
    # trailing chunk is bucket-padded: 300 -> chunks of 256 + 44 -> (256, 64)
    assert seen == [(256, 128), (64, 128)]
    # and the chunked metrics agree with per-sample inference
    singles = np.concatenate([p._predict_samples([s]) for s in samples[:32]])
    np.testing.assert_allclose(p._predict_samples(samples[:32]), singles,
                               rtol=1e-4)
    assert np.isfinite(m["mae"]) and np.isfinite(m["r2"])


def test_evaluate_trace_count_bounded(tiny_cfg):
    """Different evaluation-set sizes reuse the batch-bucket ladder instead
    of compiling one shape per size."""
    tr, _, _ = make_predictor_dataset(300, seed=3, max_len=128, max_steps=2)
    p = BGEPredictor(tiny_cfg)
    base = p.num_traces
    p.evaluate(tr[:300])          # chunks 256 + 44 -> buckets {256, 64}
    first = p.num_traces - base
    p.evaluate(tr[:290])          # 256 + 34 -> {256, 64} again: no retrace
    p.evaluate(tr[:60])           # -> bucket 64: cached
    assert p.num_traces - base == first


def test_fit_estimates_residual_spread(tiny_cfg):
    tr, _, _ = make_predictor_dataset(200, seed=4, max_len=128, max_steps=3)
    p = BGEPredictor(tiny_cfg, seed=0)
    assert p.resid_sigma == 0.0
    j = Job(job_id=0, prompt="x", prompt_tokens=[1, 2], arrival_time=0.0)
    [before] = p.predict([j])
    assert before.quantiles == ()          # untrained: degenerate
    p.fit(tr, num_steps=30, batch_size=16)
    assert p.resid_sigma > 0.0
    # per-step ladder (Fig. 2(b)): step 0 has enough train samples
    assert 0 in p.resid_by_step
    [after] = p.predict([j])
    assert after.quantiles                  # lognormal ladder attached
    assert after.quantile(0.9) > after.quantile(0.5)
    # num_traces was reset after fit: serving-path compile budget intact
    assert p.num_traces <= 2


def test_oracle_is_exact():
    o = OraclePredictor()
    j = Job(job_id=0, prompt="x", prompt_tokens=[1], arrival_time=0.0,
            true_output_len=77)
    assert o.init(j) == 77
    j.generated = [5] * 30
    assert o.iter(j) == 47


def test_encoder_separates_topics():
    """Fig. 1: same-topic sentences cluster tighter than cross-topic ones —
    even an untrained encoder shows the gap because topic vocabularies map to
    distinct token ids (structure the trained predictor exploits)."""
    from repro.data import similarity_probe_sets

    sim, dis, tok = similarity_probe_sets(40, seed=0)
    cfg = EncoderArchConfig(d_model=64, n_heads=2, n_layers=2, d_ff=128,
                            max_len=32)
    params = init_encoder(jax.random.PRNGKey(0), cfg)

    def embed(sentences):
        ml = 16
        toks = np.zeros((len(sentences), ml), np.int32)
        mask = np.zeros((len(sentences), ml), bool)
        for i, s in enumerate(sentences):
            ids = tok.encode(s, add_cls=True)[:ml]
            toks[i, : len(ids)] = ids
            mask[i, : len(ids)] = True
        cls, mean = encode(params, cfg, jnp.asarray(toks), jnp.asarray(mask))
        return np.asarray(mean)

    es, ed = embed(sim), embed(dis)
    intra = np.linalg.norm(es - es.mean(0), axis=1).mean()
    inter = np.linalg.norm(ed - ed.mean(0), axis=1).mean()
    assert intra < inter
