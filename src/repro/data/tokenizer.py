"""Deterministic word-hash tokenizer.

No external vocabulary files exist offline, so we use a stable-hash word
tokenizer: every whitespace-separated word maps to a fixed id in
``[N_SPECIAL, vocab)`` via FNV-1a.  Deterministic across runs/processes
(unlike Python's ``hash``), collision rate is acceptable at vocab 8k for the
synthetic workload, and it round-trips token *ids* (not text) which is all the
predictor and engine need.
"""
from __future__ import annotations

from typing import List, Sequence

PAD_ID = 0
CLS_ID = 1
SEP_ID = 2
UNK_ID = 3
BOS_ID = 4
EOS_ID = 5
N_SPECIAL = 8


def _fnv1a(word: str) -> int:
    h = 0xCBF29CE484222325
    for ch in word.encode("utf-8"):
        h ^= ch
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashTokenizer:
    def __init__(self, vocab_size: int = 8192):
        if vocab_size <= N_SPECIAL:
            raise ValueError("vocab too small")
        self.vocab_size = vocab_size

    def token_id(self, word: str) -> int:
        return N_SPECIAL + _fnv1a(word.lower()) % (self.vocab_size - N_SPECIAL)

    def encode(self, text: str, *, add_cls: bool = False) -> List[int]:
        ids = [self.token_id(w) for w in text.split()]
        return ([CLS_ID] + ids) if add_cls else ids

    def encode_pair(self, prompt: str, partial: Sequence[int]) -> List[int]:
        """[CLS] prompt [SEP] partial-output-token-ids — the iterative
        predictor's input format."""
        return [CLS_ID] + self.encode(prompt) + [SEP_ID] + list(partial)
