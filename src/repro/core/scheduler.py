"""Scheduling policies: FCFS, SJF (oracle one-shot), ISRTF (the paper's
contribution), and MLFQ (FastServe-style, for comparison).

A policy assigns each job a *priority* — smaller runs earlier.  ISRTF
re-predicts the remaining length every scheduling iteration (Algorithm 1
lines 11–14): ``Predictor.init`` on first sight, ``Predictor.iter`` after.

Anti-starvation: an aging term subtracts ``aging_rate * wait_seconds`` from
the effective priority so long-waiting jobs eventually run regardless of
length (paper §3.4: "policies that ... prevent starvation").
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.job import Job
from repro.core.predictor import Predictor


@dataclass
class SchedulerConfig:
    policy: str = "isrtf"  # fcfs | sjf | isrtf | mlfq
    #: tokens per scheduling iteration (paper: 50)
    window: int = 50
    #: max jobs per backend batch
    batch_size: int = 4
    #: aging: priority units (tokens) forgiven per second of waiting; 0 = off
    aging_rate: float = 0.0
    #: MLFQ quantum boundaries in generated tokens
    mlfq_levels: Tuple[int, ...] = (50, 200, 800)


class Policy:
    """Base: FCFS."""

    name = "fcfs"

    def __init__(self, cfg: SchedulerConfig, predictor: Optional[Predictor]):
        self.cfg = cfg
        self.predictor = predictor

    def priority(self, job: Job, now: float) -> float:
        return job.arrival_time

    def effective(self, job: Job, now: float) -> float:
        p = self.priority(job, now)
        job.priority = p
        job.predictions.append(p)
        if self.cfg.aging_rate > 0 and job.last_enqueue_time is not None:
            p -= self.cfg.aging_rate * max(now - job.last_enqueue_time, 0.0)
        return p


class FCFSPolicy(Policy):
    name = "fcfs"


class SJFPolicy(Policy):
    """One-shot shortest-job-first: predict once at arrival, never update
    (Qiu et al. / the paper's oracle baseline when given OraclePredictor)."""

    name = "sjf"

    def priority(self, job: Job, now: float) -> float:
        if job.priority is None:
            return float(self.predictor.init(job))
        # keep the arrival-time estimate: total predicted length minus
        # whatever has already been generated
        first = job.predictions[0] if job.predictions else job.priority
        return max(float(first) - job.tokens_generated, 0.0)


class ISRTFPolicy(Policy):
    """Iterative shortest-remaining-time-first (the paper's scheduler)."""

    name = "isrtf"

    def priority(self, job: Job, now: float) -> float:
        if job.priority is None:
            return float(self.predictor.init(job))
        return float(self.predictor.iter(job))


class MLFQPolicy(Policy):
    """FastServe-style multi-level feedback queue on service received."""

    name = "mlfq"

    def priority(self, job: Job, now: float) -> float:
        level = 0
        for bound in self.cfg.mlfq_levels:
            if job.tokens_generated >= bound:
                level += 1
        # within a level, FCFS
        return level * 1e9 + job.arrival_time


POLICIES = {
    "fcfs": FCFSPolicy,
    "sjf": SJFPolicy,
    "isrtf": ISRTFPolicy,
    "mlfq": MLFQPolicy,
}


def make_policy(cfg: SchedulerConfig, predictor: Optional[Predictor]) -> Policy:
    try:
        cls = POLICIES[cfg.policy]
    except KeyError:
        raise ValueError(f"unknown policy {cfg.policy!r}") from None
    if cls in (SJFPolicy, ISRTFPolicy) and predictor is None:
        raise ValueError(f"{cfg.policy} requires a predictor")
    return cls(cfg, predictor)


# --------------------------------------------------------------------------- #
# PriorityBuffer (paper §4.1: one priority queue per backend node)
# --------------------------------------------------------------------------- #


class PriorityBuffer:
    def __init__(self):
        self._heaps: Dict[int, List] = {}
        self._count = itertools.count()

    def push(self, node: int, prio: float, job: Job) -> None:
        heapq.heappush(self._heaps.setdefault(node, []),
                       (prio, next(self._count), job))

    def pop_batch(self, node: int, k: int) -> List[Job]:
        heap = self._heaps.get(node, [])
        out = []
        while heap and len(out) < k:
            out.append(heapq.heappop(heap)[2])
        return out

    def depth(self, node: int) -> int:
        return len(self._heaps.get(node, []))


# --------------------------------------------------------------------------- #
# Preemption (paper §3.4 / Appendix A)
# --------------------------------------------------------------------------- #


@dataclass
class PreemptionConfig:
    """Knobs for 'adjusting the frequency of preemption' (paper §1, §3.4)."""

    enabled: bool = True
    #: a waiting job must beat a running job's priority by this many tokens
    #: (paper §3.4: preemption should be rare; one window's worth of tokens)
    margin: float = 50.0
    #: at most this fraction of a batch may be preempted per iteration
    max_fraction: float = 0.25
    #: per-preemption cost charged when the victim resumes (KV recompute),
    #: expressed in prompt-tokens re-prefilled
    recompute_tokens: bool = True


def select_preemptions(
    running: Sequence[Tuple[float, Job]],
    waiting: Sequence[Tuple[float, Job]],
    cfg: PreemptionConfig,
) -> List[Tuple[Job, Job]]:
    """Given (priority, job) for the running batch and the waiting queue,
    return [(victim, replacement), ...] — lowest-priority running jobs are
    displaced by strictly-higher-priority waiters (vLLM's priority preemption
    with our margin/frequency knobs)."""
    if not cfg.enabled or not running or not waiting:
        return []
    budget = max(int(len(running) * cfg.max_fraction), 0)
    victims = sorted(running, key=lambda t: -t[0])  # worst running first
    claimants = sorted(waiting, key=lambda t: t[0])  # best waiting first
    swaps: List[Tuple[Job, Job]] = []
    for (rp, rjob), (wp, wjob) in zip(victims, claimants):
        if len(swaps) >= budget:
            break
        if wp + cfg.margin < rp:
            swaps.append((rjob, wjob))
    return swaps
