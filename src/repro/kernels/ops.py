"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python/XLA for correctness validation.  On TPU they
compile through Mosaic.  ``interpret`` is auto-detected from the backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssm_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("window", "block_k", "mesh",
                                             "shard_axis"))
def flash_decode(q, k, v, *, kv_len, q_offset,
                 window: Optional[int] = None, block_k: int = 128,
                 mesh=None, shard_axis: str = "model"):
    """Flash-decode dispatch.  With ``mesh`` (a static arg, so single- and
    multi-device callers never share a stale trace) the kernel runs
    ``shard_map``-ped over ``shard_axis`` with Q/KV heads partitioned —
    bit-identical per head to the single-device kernel."""
    if mesh is not None:
        return _dec.flash_decode_sharded(
            q, k, v, kv_len=kv_len, q_offset=q_offset, mesh=mesh,
            axis=shard_axis, window=window, block_k=block_k,
            interpret=_interpret(),
        )
    return _dec.flash_decode(
        q, k, v, kv_len=kv_len, q_offset=q_offset, window=window,
        block_k=block_k, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("window", "block_k"))
def flash_decode_int8(q, k, v, k_scale, v_scale, *, kv_len, q_offset,
                      window: Optional[int] = None, block_k: int = 128):
    """Decode attention over an int8-quantized KV cache (the §Perf serving
    recipe): HBM reads are int8, dequantization fuses into the block load."""
    return _dec.flash_decode_int8(
        q, k, v, k_scale, v_scale, kv_len=kv_len, q_offset=q_offset,
        window=window, block_k=block_k, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, a, Bm, Cm, *, chunk: int = 256):
    return _ssd.ssd_scan(x, a, Bm, Cm, chunk=chunk, interpret=_interpret())
