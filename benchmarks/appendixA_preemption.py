"""Paper Appendix A (Table 6): minimum batch size that triggers preemption.

The paper saturates each model with 10K req/s and grows the batch until the
vLLM memory limit forces a preemption.  Our engine's KV memory model
(kv_bytes/token × resident tokens vs HBM budget) predicts the onset batch:
    onset ≈ capacity_tokens / avg_resident_tokens_per_request
and we verify the *measured* onset in the simulator matches the paper's
Table 6 within 2x (the workload's prompt/response mix differs from theirs).
Also reproduces §3.4's conclusion: at the FabriX rate (<3 req/s) preemption
probability is ~0.
"""
from __future__ import annotations

import numpy as np

from repro.core import PreemptionConfig
from repro.data import WorkloadGenerator
from repro.simulate import PROFILES, ExperimentConfig, run_experiment

from benchmarks.common import save_results


def run(quick: bool = False):
    gen = WorkloadGenerator(seed=0)
    reqs = gen.sample_requests(400)
    avg_tokens = float(np.mean([len(r.prompt_tokens) + r.true_output_len
                                for r in reqs]))
    rows = []
    for name, p in PROFILES.items():
        cap = p.kv_capacity_tokens()
        predicted_onset = cap / avg_tokens
        rows.append({
            "model": name,
            "paper_onset_batch": p.preempt_batch,
            "paper_mem_limit": p.mem_limit_frac,
            "kv_bytes_per_token": p.kv_bytes_per_token,
            "capacity_tokens": cap,
            "predicted_onset_batch": round(predicted_onset, 1),
            "onset_ratio_vs_paper": round(predicted_onset / p.preempt_batch, 2),
            "within_2x_of_paper": 0.5
            <= predicted_onset / p.preempt_batch <= 2.0,
        })

    # §3.4: memory preemptions at realistic rates are ~zero
    cfg = ExperimentConfig(model="lam13", policy="fcfs", n_requests=100,
                           batch_size=4, rate_override=3.0, seed=1,
                           predictor="none",
                           preemption=PreemptionConfig(enabled=False))
    m = run_experiment(cfg)
    rows.append({
        "model": "lam13 @ 3 req/s (FabriX max rate)",
        "memory_preemptions": m["mem_preemptions"],
        "conclusion": "preemption probability ~0 at real-world rates",
    })
    save_results("appendixA_preemption", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
