"""Decode-path exactness: prefill + decode_step must reproduce forward()."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import decode_step, forward, init_cache, init_params, prefill

TOL = 5e-5


def _batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, cfg.frontend_tokens,
                                                  cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder.n_frames,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S)
    cache = init_cache(cfg, B, 64)
    lg, cache = prefill(params, cfg, batch, cache)
    full, _ = forward(params, cfg, batch)
    assert float(jnp.max(jnp.abs(lg[:, -1] - full[:, -1]))) < TOL

    toks = batch["tokens"]
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 3), 0, cfg.vocab_size)
    for i in range(3):
        lgd, cache = decode_step(params, cfg, nxt[:, i : i + 1], cache)
        ext = dict(batch)
        ext["tokens"] = jnp.concatenate([toks, nxt[:, : i + 1]], 1)
        lge, _ = forward(params, cfg, ext)
        err = float(jnp.max(jnp.abs(lgd[:, 0] - lge[:, -1])))
        assert err < TOL, (arch, i, err)


def test_heterogeneous_slot_lengths():
    """Continuous batching: slots at different depths decode identically to
    isolated per-slot decoding."""
    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    t0 = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab_size)
    t1 = jax.random.randint(jax.random.PRNGKey(6), (1, 12), 0, cfg.vocab_size)
    c0 = init_cache(cfg, 1, 64)
    c1 = init_cache(cfg, 1, 64)
    _, c0 = prefill(params, cfg, {"tokens": t0}, c0)
    _, c1 = prefill(params, cfg, {"tokens": t1}, c1)
    merged = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1)
        if a.ndim > 1 else jnp.concatenate([a, b]),
        c0, c1,
    )
    nxt = jnp.array([[3], [7]], jnp.int32)
    lgm, _ = decode_step(params, cfg, nxt, merged)
    l0, _ = decode_step(params, cfg, nxt[:1], c0)
    l1, _ = decode_step(params, cfg, nxt[1:], c1)
    assert float(jnp.max(jnp.abs(lgm[0] - l0[0]))) < TOL
    assert float(jnp.max(jnp.abs(lgm[1] - l1[0]))) < TOL


def test_ring_buffer_equals_full_within_window():
    """With a ring buffer >= attention window, sliding-window decode must be
    bit-equal to the full-cache SWA decode."""
    cfg = get_config("mixtral-8x7b").reduced()  # swa_window=64 (reduced)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 8
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S)
    full = init_cache(cfg, B, 256)        # swa -> buffer = window = 64 < 256
    assert full["kv"].k.shape[2] == cfg.swa_window
    _, full = prefill(params, cfg, batch, full)
    big = init_cache(cfg, B, 32)          # buffer 32 >= any reachable len
    assert not big["kv"].ring
    _, big = prefill(params, cfg, batch, big)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 5), 0, cfg.vocab_size)
    for i in range(5):
        lr, full = decode_step(params, cfg, nxt[:, i : i + 1], full)
        lf, big = decode_step(params, cfg, nxt[:, i : i + 1], big)
        assert float(jnp.max(jnp.abs(lr - lf))) < TOL, i


def test_long_context_ring_decode_stays_finite():
    """Ring decode far past the window: no NaNs, mask arithmetic holds."""
    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 1, 10_000, sliding_window=8)
    assert cache["kv"].ring
    step = jax.jit(lambda t, c: decode_step(params, cfg, t, c))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 40), 0,
                              cfg.vocab_size)
    for i in range(40):
        lg, cache = step(toks[:, i : i + 1], cache)
        assert not jnp.any(jnp.isnan(lg))
    assert int(cache["len"][0]) == 40
