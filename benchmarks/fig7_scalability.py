"""Paper Fig. 7: peak throughput vs number of backend workers.

Peak throughput = the highest request rate at which mean queuing delay stays
≤ 0.5 s.  The paper scales 10 → 50 H100 workers (batch 4, LlaMA2-13B via
ISRTF) and reports near-linear scaling: 2.31 RPS @ 10 workers → 18.77 RPS
@ 50.  We binary-search the peak rate per worker count on the calibrated
simulator (the H100 point is ~3.7x an A100 on decode bandwidth; we report
normalised scaling efficiency, which is the paper's actual claim)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.simulate import ExperimentConfig, run_experiment

from benchmarks.common import save_results

QDELAY_SLO = 0.5  # seconds


def peak_rate(n_workers: int, *, n_req: int, lo: float, hi: float,
              iters: int = 7) -> float:
    """Binary search the highest rate meeting the queuing-delay SLO."""

    def ok(rate: float) -> bool:
        from repro.simulate.profiles import H100_SPEEDUP

        cfg = ExperimentConfig(
            model="lam13", policy="isrtf", n_requests=n_req,
            batch_size=4, n_nodes=n_workers, seed=13, rate_override=rate,
            hw_speedup=H100_SPEEDUP,  # the paper's Fig-7 cluster is H100s
        )
        m = run_experiment(cfg)
        return m["queuing_delay_mean"] <= QDELAY_SLO

    if not ok(lo):
        return lo
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return lo


def run(quick: bool = False):
    workers = [10, 30, 50] if quick else [10, 20, 30, 40, 50]
    rows = []
    base = None
    for w in workers:
        # steady-state: enough requests to cover several minutes of traffic
        n_req = (20 if quick else 40) * w
        rate = peak_rate(w, n_req=n_req, lo=0.02 * w, hi=2.5 * w)
        if base is None:
            base = (w, rate)
        eff = (rate / base[1]) / (w / base[0])
        rows.append({
            "n_workers": w,
            "peak_rps": round(rate, 3),
            "scaling_efficiency_vs_first": round(eff, 3),
        })
    rows.append({
        "paper": "H100: 2.31 RPS @ 10 workers -> 18.77 RPS @ 50 "
                 "(near-linear, eff ~1.6 reported super-linear)",
    })
    save_results("fig7_scalability", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
