"""Request arrival processes.

The paper analyses 200k+ FabriX trace points and finds inter-arrival times
follow a Gamma distribution (shape α=0.73, scale β=10.41 s) much better than
a Poisson process — bursty arrivals (α < 1 means over-dispersion).  We expose
both processes, a method-of-moments/MLE fitter, and a log-likelihood
comparison used by the Fig. 4 benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Tuple

import numpy as np

#: values fitted on the FabriX trace in the paper
FABRIX_ALPHA = 0.73
FABRIX_SCALE = 10.41


@dataclass(frozen=True)
class GammaArrivals:
    alpha: float = FABRIX_ALPHA
    scale: float = FABRIX_SCALE

    @property
    def mean_interval(self) -> float:
        return self.alpha * self.scale

    def rate_scaled(self, target_rate: float) -> "GammaArrivals":
        """Same burstiness (alpha), rescaled so mean rate = target (req/s)."""
        return GammaArrivals(self.alpha, 1.0 / (target_rate * self.alpha))

    def sample_intervals(self, n: int, rng: np.random.RandomState) -> np.ndarray:
        return rng.gamma(self.alpha, self.scale, size=n)

    def sample_arrival_times(self, n: int, rng: np.random.RandomState) -> np.ndarray:
        return np.cumsum(self.sample_intervals(n, rng))


@dataclass(frozen=True)
class PoissonArrivals:
    rate: float  # req/s

    @property
    def mean_interval(self) -> float:
        return 1.0 / self.rate

    def sample_intervals(self, n: int, rng: np.random.RandomState) -> np.ndarray:
        return rng.exponential(1.0 / self.rate, size=n)

    def sample_arrival_times(self, n: int, rng: np.random.RandomState) -> np.ndarray:
        return np.cumsum(self.sample_intervals(n, rng))


def diurnal_arrival_times(n: int, mean_rate: float,
                          rng: np.random.RandomState, *,
                          amplitude: float = 0.6,
                          period_s: float = 3600.0,
                          noise_sigma: float = 0.0,
                          grid_points: int = 4096) -> np.ndarray:
    """Inhomogeneous-Poisson arrivals under a diurnal (sinusoidal) rate
    curve, via integrated-rate inversion.

        rate(t) = mean_rate * (1 + amplitude * sin(2π t / period_s))
                  [* lognormal(noise_sigma) jitter per grid cell]

    Unit-rate exponential marks are mapped through the inverse of the
    cumulative rate Λ(t) (trapezoid-integrated on a time grid, inverted
    with ``np.interp``) — the standard time-change construction, fully
    vectorized: one million arrivals cost two cumsums and an interp.
    Returned times are sorted; the long-run mean rate is ``mean_rate``.
    """
    assert n > 0 and mean_rate > 0
    assert 0.0 <= amplitude < 1.0, "amplitude >= 1 makes the rate negative"
    # unit-rate event marks, drawn once; the grid (re)extends to cover them
    marks = np.cumsum(rng.exponential(1.0, size=n))
    horizon = 1.25 * n / mean_rate + period_s
    while True:
        t = np.linspace(0.0, horizon, grid_points)
        rate = mean_rate * (1.0 + amplitude *
                            np.sin(2.0 * np.pi * t / period_s))
        if noise_sigma > 0:
            rate = rate * rng.lognormal(-0.5 * noise_sigma ** 2,
                                        noise_sigma, size=grid_points)
        cum = np.concatenate(
            ([0.0], np.cumsum(0.5 * (rate[1:] + rate[:-1]) * np.diff(t))))
        if cum[-1] >= marks[-1]:
            return np.interp(marks, cum, t)
        horizon *= 2.0


# --------------------------------------------------------------------------- #
# Fitting
# --------------------------------------------------------------------------- #


def fit_gamma(intervals: np.ndarray, iters: int = 100) -> Tuple[float, float]:
    """MLE gamma fit via Newton iterations on the digamma equation
    (scipy-free).  Returns (alpha, scale)."""
    x = np.asarray(intervals, dtype=np.float64)
    x = x[x > 0]
    m = x.mean()
    logm = np.log(m)
    meanlog = np.log(x).mean()
    s = logm - meanlog
    # initial guess (Minka 2002)
    a = (3 - s + np.sqrt((s - 3) ** 2 + 24 * s)) / (12 * s)
    for _ in range(iters):
        num = np.log(a) - _digamma(a) - s
        den = 1.0 / a - _trigamma(a)
        step = num / den
        a_new = a - step
        if a_new <= 0:
            a_new = a / 2
        if abs(a_new - a) < 1e-12:
            a = a_new
            break
        a = a_new
    return float(a), float(m / a)


def _digamma(x: float) -> float:
    """Digamma via asymptotic expansion with recurrence shift."""
    r = 0.0
    while x < 6:
        r -= 1.0 / x
        x += 1
    f = 1.0 / (x * x)
    return r + np.log(x) - 0.5 / x - f * (
        1.0 / 12 - f * (1.0 / 120 - f * (1.0 / 252 - f / 240))
    )


def _trigamma(x: float) -> float:
    r = 0.0
    while x < 6:
        r += 1.0 / (x * x)
        x += 1
    f = 1.0 / (x * x)
    return r + 1.0 / x + f / 2 + f / x * (
        1.0 / 6 - f * (1.0 / 30 - f * (1.0 / 42 - f / 30))
    )


def _loggamma(a: float) -> float:
    """Stirling with shift."""
    shift = 0.0
    x = a
    while x < 8:
        shift -= np.log(x)
        x += 1
    return float(
        shift
        + 0.5 * np.log(2 * np.pi)
        + (x - 0.5) * np.log(x)
        - x
        + 1.0 / (12 * x)
        - 1.0 / (360 * x ** 3)
    )


def gamma_loglik(intervals: np.ndarray, alpha: float, scale: float) -> float:
    x = np.asarray(intervals, dtype=np.float64)
    x = x[x > 0]
    return float(
        np.sum(
            (alpha - 1) * np.log(x) - x / scale - alpha * np.log(scale)
            - _loggamma(alpha)
        )
    )


def exponential_loglik(intervals: np.ndarray) -> float:
    """Best-fit exponential (= Poisson process) log-likelihood."""
    x = np.asarray(intervals, dtype=np.float64)
    x = x[x > 0]
    lam = 1.0 / x.mean()
    return float(np.sum(np.log(lam) - lam * x))
