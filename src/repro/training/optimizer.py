"""Pure-JAX optimizers and LR schedules (no optax dependency).

AdamW with decoupled weight decay, global-norm gradient clipping, and
warmup-cosine scheduling — the standard LLM training stack.  Optimizer state
is a pytree congruent with the parameters, so it shards identically under
pjit (ZeRO-style sharding falls out of the partition rules).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    #: schedule: constant | cosine | linear
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:  # cosine
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * 0.5 * (
            1.0 + jnp.cos(math.pi * frac)
        )
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), t
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    trainable_mask: Optional[Any] = None,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics).

    ``trainable_mask``: pytree of bools congruent with params; False leaves
    are left untouched (the paper freezes the BGE encoder and trains only the
    FC head — this is how).
    """
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, t):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - lr * delta
        newp = jnp.where(t, newp, p.astype(jnp.float32)).astype(p.dtype)
        m = jnp.where(t, m, 0.0)
        v = jnp.where(t, v, 0.0)
        return newp, m, v

    if trainable_mask is None:
        trainable_mask = jax.tree_util.tree_map(lambda _: True, params)
    flat = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu,
                                  trainable_mask)
    # unzip the 3-tuples
    newp = jax.tree_util.tree_map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree_util.tree_map(lambda t: t[1], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree_util.tree_map(lambda t: t[2], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return newp, AdamWState(step=step, mu=newm, nu=newv), metrics
