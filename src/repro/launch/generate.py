"""Stand-alone request generator (paper §6.1: "we have included a
stand-alone generator in our public code for future research").

Emits a JSONL trace of requests with Gamma(0.73, 10.41) arrivals — the
FabriX-calibrated process — which ``repro.launch.serve`` replays.

    python -m repro.launch.generate --n 200 --rate 2.0 --out trace.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.data import GammaArrivals, PoissonArrivals, WorkloadGenerator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100)
    ap.add_argument("--rate", type=float, default=None,
                    help="mean req/s (default: the raw FabriX fit)")
    ap.add_argument("--process", default="gamma",
                    choices=["gamma", "poisson"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="-")
    args = ap.parse_args()

    rng = np.random.RandomState(args.seed)
    gen = WorkloadGenerator(seed=args.seed)
    if args.process == "gamma":
        proc = GammaArrivals()
        if args.rate:
            proc = proc.rate_scaled(args.rate)
    else:
        proc = PoissonArrivals(rate=args.rate or 1.0)
    times = proc.sample_arrival_times(args.n, rng)

    out = sys.stdout if args.out == "-" else open(args.out, "w")
    for t in times:
        r = gen.sample_request()
        rec = {
            "request_id": r.request_id,
            "arrival_time": round(float(t), 4),
            "prompt": r.prompt,
            "prompt_tokens": r.prompt_tokens,
            "max_tokens": r.true_output_len,
            # latents retained for offline analysis (never fed to ELIS)
            "_task": r.task,
            "_topic": r.topic,
        }
        out.write(json.dumps(rec) + "\n")
    if out is not sys.stdout:
        out.close()
        print(f"wrote {args.n} requests to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
