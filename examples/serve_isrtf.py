"""End-to-end serving driver (the paper's system, reduced scale).

    PYTHONPATH=src python examples/serve_isrtf.py [--jobs 12]

Serves a stream of Gamma-arrival requests on the live JAX engine under all
three schedulers (FCFS, ISRTF, SJF-oracle) through the online
:class:`ElisServer` API and prints the JCT comparison — the full ELIS
pipeline: workload -> frontend (Algorithm 1) -> priority buffer ->
continuous-batching engine -> iterative re-prediction.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElisServer,
    FrontendConfig,
    OraclePredictor,
    PreemptionConfig,
    Request,
    RequestOptions,
    SchedulerConfig,
    summarize,
)
from repro.data import GammaArrivals, HashTokenizer
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params


def make_requests(n, seed=0, max_tokens=40):
    tok = HashTokenizer()
    rng = np.random.RandomState(seed)
    arrivals = GammaArrivals().rate_scaled(1.5).sample_arrival_times(n, rng)
    reqs = []
    for i in range(n):
        length = int(rng.choice([6, 12, 40], p=[0.5, 0.3, 0.2]))
        text = f"request {i} with target verbosity {length}"
        reqs.append(Request(
            prompt=text, prompt_tokens=tok.encode(text),
            arrival_time=float(arrivals[i]),
            true_output_len=length,
            options=RequestOptions(max_tokens=max_tokens)))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--window", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    results = {}
    for policy in ("fcfs", "isrtf", "sjf"):
        engine = InferenceEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=256, max_output=40, eos_id=-1,
            respect_job_max=True))
        server = ElisServer(
            FrontendConfig(
                n_nodes=1,
                scheduler=SchedulerConfig(policy=policy, window=args.window,
                                          batch_size=2),
                preemption=PreemptionConfig(enabled=policy != "fcfs"),
            ),
            OraclePredictor() if policy != "fcfs" else None,
            EngineExecutor({0: engine}),
        )
        for r in make_requests(args.jobs):
            server.submit(r)
        m = summarize(server.drain())
        results[policy] = m
        print(f"{policy:6s}: mean JCT {m['jct_mean']:7.2f}s  "
              f"queue {m['queuing_delay_mean']:6.2f}s  "
              f"preemptions {m['preemptions']:.0f}")

    base = results["fcfs"]["jct_mean"]
    for policy in ("isrtf", "sjf"):
        gain = 100 * (base - results[policy]["jct_mean"]) / base
        print(f"{policy} vs fcfs: {gain:+.1f}% JCT")


if __name__ == "__main__":
    main()
