"""Distribution layer: production mesh, partition rules, dry-run, drivers."""
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    batch_axes,
    make_mesh,
    make_production_mesh,
    pod_meshes,
)
from repro.launch.shapes import LONG_CONTEXT_WINDOW, SHAPES, InputShape, input_specs, supported

__all__ = [
    "HBM_BW",
    "ICI_BW",
    "InputShape",
    "LONG_CONTEXT_WINDOW",
    "PEAK_FLOPS_BF16",
    "SHAPES",
    "batch_axes",
    "input_specs",
    "make_mesh",
    "make_production_mesh",
    "pod_meshes",
    "supported",
]
