"""Model assembly for all assigned architecture families.

Families and their block structure:
  dense / vlm        : [norm -> GQA attn -> norm -> gated MLP] x L
  moe                : [norm -> GQA attn -> norm -> MoE FFN] x L
  ssm                : [norm -> Mamba2] x L
  hybrid (zamba2)    : 13 groups of (6 x [norm -> Mamba2]) each followed by a
                       weight-SHARED attention block, + 3 tail Mamba2 layers
  audio (whisper)    : encoder stack over stub frame embeddings + decoder with
                       self- and cross-attention, learned positions, LayerNorm

All layer stacks are ``lax.scan``-stacked: parameters carry a leading layer
axis, which keeps HLO size (and 512-way SPMD compile time) bounded.

Public API:
  init_params(key, cfg)                       -> params pytree
  forward(params, cfg, batch)                 -> (logits, aux_loss)
  init_cache(cfg, batch, max_len, dtype)      -> cache pytree
  prefill(params, cfg, batch, cache)          -> (last_logits, cache)
  decode_step(params, cfg, tokens, cache)     -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.scanning import layer_scan

Params = Dict[str, Any]
Cache = Dict[str, Any]


KVCache = L.KVCache


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# =========================================================================== #
# Init
# =========================================================================== #


def _init_dense_layer(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_norm(cfg, cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp_norm": L.init_norm(cfg, cfg.d_model, dtype),
    }
    if cfg.moe.enabled:
        p["moe"] = M.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def _init_ssm_layer(key, cfg, dtype) -> Params:
    return {
        "norm": L.init_norm(cfg, cfg.d_model, dtype),
        "ssm": S.init_ssm(key, cfg, dtype),
    }


def _stack_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def hybrid_layout(cfg) -> Tuple[int, int, int]:
    """(n_groups, inner_per_group, n_tail) for the hybrid family."""
    every = cfg.hybrid.attn_every
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    return n_groups, every, tail


def init_params(key, cfg) -> Params:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                         dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stack_init(
            lambda k: _init_dense_layer(k, cfg, dtype), keys[2], cfg.n_layers
        )
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(
            lambda k: _init_ssm_layer(k, cfg, dtype), keys[2], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        n_groups, inner, tail = hybrid_layout(cfg)
        grp_keys = jax.random.split(keys[2], n_groups)
        params["groups"] = jax.vmap(
            lambda k: _stack_init(lambda kk: _init_ssm_layer(kk, cfg, dtype),
                                  k, inner)
        )(grp_keys)
        if tail:
            params["tail"] = _stack_init(
                lambda k: _init_ssm_layer(k, cfg, dtype), keys[3], tail
            )
        params["shared_attn"] = _init_dense_layer(keys[4], cfg, dtype)
    elif cfg.family == "audio":
        enc = cfg.encoder
        params["enc_pos"] = L.embed_init(keys[3], enc.n_frames, cfg.d_model,
                                         dtype)
        params["pos_embed"] = L.embed_init(
            keys[4], cfg.max_position_embeddings, cfg.d_model, dtype
        )

        def init_enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "attn_norm": L.init_norm(cfg, cfg.d_model, dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "mlp_norm": L.init_norm(cfg, cfg.d_model, dtype),
                "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                  dtype),
            }

        def init_dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "attn_norm": L.init_norm(cfg, cfg.d_model, dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "cross_norm": L.init_norm(cfg, cfg.d_model, dtype),
                "cross": L.init_cross_attention(k2, cfg, dtype),
                "mlp_norm": L.init_norm(cfg, cfg.d_model, dtype),
                "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                  dtype),
            }

        params["enc_layers"] = _stack_init(init_enc_layer, keys[5],
                                           enc.n_layers)
        params["enc_final_norm"] = L.init_norm(cfg, cfg.d_model, dtype)
        params["layers"] = _stack_init(init_dec_layer, keys[6], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


# =========================================================================== #
# Embedding / unembedding
# =========================================================================== #


def embed_inputs(params: Params, cfg, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden (B,S,d), positions (B,S) or (3,B,S))."""
    tokens = batch["tokens"]
    h = params["embed"][tokens]
    if cfg.frontend == "vision_stub" and "embeds" in batch:
        h = jnp.concatenate([batch["embeds"].astype(h.dtype), h], axis=1)
    s = h.shape[1]
    if "positions" in batch and batch["positions"] is not None:
        pos = batch["positions"]
    else:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (h.shape[0], s))
    if cfg.rope_type == "learned":
        h = h + params["pos_embed"][pos]
    return h, pos


def unembed(params: Params, cfg, h: jnp.ndarray) -> jnp.ndarray:
    h = L.apply_norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


# =========================================================================== #
# Layer bodies
# =========================================================================== #


def _dense_body(cfg, attn_impl, moe_impl, lp: Params, x, cos_sin,
                cache=None, cur_index=None, active=None, valid_len=None,
                mesh=None):
    h = L.apply_norm(cfg, lp["attn_norm"], x)
    attn_out, kv = L.attention_block(
        lp["attn"], cfg, h, cos_sin, cache=cache, cur_index=cur_index,
        attn_impl=attn_impl, active=active, valid_len=valid_len, mesh=mesh,
    )
    x = x + attn_out
    h = L.apply_norm(cfg, lp["mlp_norm"], x)
    if cfg.moe.enabled:
        out, aux = M.moe_block(lp["moe"], cfg, h, impl=moe_impl)
    else:
        out, aux = L.mlp_block(lp["mlp"], cfg, h), jnp.float32(0)
    return x + out, kv, aux


def _ssm_body(cfg, impl, lp: Params, x, state=None, active=None):
    h = L.apply_norm(cfg, lp["norm"], x)
    if state is None:
        out, _ = S.ssm_forward(lp["ssm"], cfg, h, impl=impl)
        return x + out, None
    out, new_state = S.ssm_decode_step(lp["ssm"], cfg, h, state)
    if active is not None:
        # frozen decode slots keep their recurrent state bit-identical
        new_state = jax.tree_util.tree_map(
            lambda old, new: jnp.where(
                active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
            state, new_state)
    return x + out, new_state


# =========================================================================== #
# Forward (training / full-sequence)
# =========================================================================== #


def forward(params: Params, cfg, batch: Dict, *, attn_impl: str = "xla",
            moe_impl: str = "dense",
            remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced full-sequence forward.  Returns (logits, aux_loss).

    ``remat=True`` rematerialises each scanned layer body on the backward
    pass — only the per-layer residual stream is saved (training memory).
    """
    ckpt = (lambda f: jax.checkpoint(f, prevent_cse=False)) if remat else (
        lambda f: f)
    h, pos = embed_inputs(params, cfg, batch)
    cos_sin = L.positional_cos_sin(cfg, pos) if cfg.rope_type in ("rope", "mrope") else None

    if cfg.family in ("dense", "moe", "vlm"):
        @ckpt
        def body(carry, lp):
            x, aux = carry
            x, _, a = _dense_body(cfg, attn_impl, moe_impl, lp, x, cos_sin)
            return (x, aux + a), None

        (h, aux), _ = layer_scan(body, (h, jnp.float32(0)), params["layers"])
    elif cfg.family == "ssm":
        @ckpt
        def body(x, lp):
            x, _ = _ssm_body(cfg, attn_impl, lp, x)
            return x, None

        h, _ = layer_scan(body, h, params["layers"])
        aux = jnp.float32(0)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        @ckpt
        def inner(x, lp):
            x, _ = _ssm_body(cfg, attn_impl, lp, x)
            return x, None

        @ckpt
        def group(x, gp):
            x, _ = layer_scan(inner, x, gp)
            x, _, _ = _dense_body(cfg, attn_impl, moe_impl, shared, x, cos_sin)
            return x, None

        h, _ = layer_scan(group, h, params["groups"])
        if "tail" in params:
            h, _ = layer_scan(inner, h, params["tail"])
        aux = jnp.float32(0)
    elif cfg.family == "audio":
        enc_out = encode_audio(params, cfg, batch["frames"], attn_impl,
                               remat=remat)

        @ckpt
        def body(x, lp):
            hh = L.apply_norm(cfg, lp["attn_norm"], x)
            attn_out, _ = L.attention_block(lp["attn"], cfg, hh, None,
                                            attn_impl=attn_impl)
            x = x + attn_out
            hh = L.apply_norm(cfg, lp["cross_norm"], x)
            enc_kv = L.encode_cross_kv(lp["cross"], cfg, enc_out)
            x = x + L.cross_attention_block(lp["cross"], cfg, hh, enc_kv)
            hh = L.apply_norm(cfg, lp["mlp_norm"], x)
            return x + L.mlp_block(lp["mlp"], cfg, hh), None

        h, _ = layer_scan(body, h, params["layers"])
        aux = jnp.float32(0)
    else:
        raise ValueError(cfg.family)

    return unembed(params, cfg, h), aux


def encode_audio(params: Params, cfg, frames: jnp.ndarray,
                 attn_impl: str = "xla", remat: bool = False) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, n_frames, d)."""
    ckpt = (lambda f: jax.checkpoint(f, prevent_cse=False)) if remat else (
        lambda f: f)
    h = frames.astype(_dtype(cfg)) + params["enc_pos"][None, : frames.shape[1]]

    @ckpt
    def body(x, lp):
        hh = L.apply_norm(cfg, lp["attn_norm"], x)
        q = hh @ lp["attn"]["wq"]
        k = hh @ lp["attn"]["wk"]
        v = hh @ lp["attn"]["wv"]
        b, s, d = hh.shape
        nh, hd = cfg.n_heads, cfg.head_dim
        out = L.sdpa(
            q.reshape(b, s, nh, hd), k.reshape(b, s, cfg.n_kv_heads, hd),
            v.reshape(b, s, cfg.n_kv_heads, hd), causal=False,
        )
        x = x + out.reshape(b, s, nh * hd) @ lp["attn"]["wo"]
        hh = L.apply_norm(cfg, lp["mlp_norm"], x)
        return x + L.mlp_block(lp["mlp"], cfg, hh), None

    h, _ = layer_scan(body, h, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_final_norm"], h)


# =========================================================================== #
# KV / state caches
# =========================================================================== #


def kv_buffer_len(cfg, max_len: int) -> int:
    """Physical KV buffer length: ring-bounded for SWA / sliding-window mode."""
    if cfg.attention_type == "swa":
        return min(max_len, cfg.swa_window)
    return max_len


def init_cache(cfg, batch: int, max_len: int, dtype=None,
               *, sliding_window: Optional[int] = None,
               kv_dtype: Optional[str] = None) -> Cache:
    """Build the decode cache.  ``sliding_window`` forces a ring buffer of the
    given size (the long_500k carve-in for full-attention archs).
    ``kv_dtype="int8"`` allocates a quantized cache (beyond-paper §Perf)."""
    dtype = dtype or _dtype(cfg)
    # per-slot lengths: decode slots advance independently (continuous batching)
    cache: Cache = {"len": jnp.zeros((batch,), jnp.int32)}
    kh, hd = cfg.n_kv_heads, cfg.head_dim

    def kv(n_sites, buf_len, ring):
        if kv_dtype == "int8":
            return KVCache(
                jnp.zeros((n_sites, batch, buf_len, kh, hd), jnp.int8),
                jnp.zeros((n_sites, batch, buf_len, kh, hd), jnp.int8),
                ring,
                jnp.zeros((n_sites, batch, buf_len), jnp.float32),
                jnp.zeros((n_sites, batch, buf_len), jnp.float32),
            )
        return KVCache(
            jnp.zeros((n_sites, batch, buf_len, kh, hd), dtype),
            jnp.zeros((n_sites, batch, buf_len, kh, hd), dtype),
            ring,
        )

    if cfg.family in ("dense", "moe", "vlm"):
        buf = kv_buffer_len(cfg, max_len)
        if sliding_window is not None:
            buf = min(buf, sliding_window)
        ring = buf < max_len
        cache["kv"] = kv(cfg.n_layers, buf, ring)
    elif cfg.family == "ssm":
        cache["ssm"] = jax.vmap(
            lambda _: S.init_ssm_state(cfg, batch, dtype)
        )(jnp.arange(cfg.n_layers))
    elif cfg.family == "hybrid":
        n_groups, inner, tail = hybrid_layout(cfg)
        cache["groups_ssm"] = jax.vmap(
            lambda _: jax.vmap(lambda __: S.init_ssm_state(cfg, batch, dtype))(
                jnp.arange(inner)
            )
        )(jnp.arange(n_groups))
        if tail:
            cache["tail_ssm"] = jax.vmap(
                lambda _: S.init_ssm_state(cfg, batch, dtype)
            )(jnp.arange(tail))
        buf = kv_buffer_len(cfg, max_len)
        ring = buf < max_len
        cache["kv"] = kv(n_groups, buf, ring)
    elif cfg.family == "audio":
        buf = min(max_len, cfg.max_position_embeddings)
        cache["kv"] = kv(cfg.n_layers, buf, False)
        # cross-attention K/V computed once at prefill
        nf = cfg.encoder.n_frames
        chd = cfg.d_model // cfg.n_heads
        cache["cross_kv"] = KVCache(
            jnp.zeros((cfg.n_layers, batch, nf, cfg.n_heads, chd), dtype),
            jnp.zeros((cfg.n_layers, batch, nf, cfg.n_heads, chd), dtype),
        )
    return cache


# =========================================================================== #
# Prefill
# =========================================================================== #


def prefill(params: Params, cfg, batch: Dict, cache: Cache,
            *, attn_impl: str = "xla", moe_impl: str = "dense",
            last_index: Optional[jnp.ndarray] = None, mesh=None):
    """Process the full prompt, fill the cache, return last-position logits.

    ``last_index`` (B,) selects the position whose logits are returned —
    engines right-pad prompts to buckets and need the *true* last position.

    ``mesh`` marks a sharded (TP) caller.  The prefill-side Pallas kernels
    (flash_attention, ssd_scan) are single-device, so under a mesh
    ``attn_impl="pallas"`` downgrades to ``"xla"`` here — numerics are
    identical either way (the xla==pallas identity contract, CI-asserted)
    and prefill is off the steady-state decode hot loop.  Mesh-aware decode
    stays on the real kernel via :func:`decode_step` (DESIGN.md §11).
    """
    if mesh is not None and attn_impl == "pallas":
        attn_impl = "xla"
    h, pos = embed_inputs(params, cfg, batch)
    s = h.shape[1]
    cos_sin = L.positional_cos_sin(cfg, pos) if cfg.rope_type in ("rope", "mrope") else None

    if cfg.family in ("dense", "moe", "vlm"):
        kvc = cache["kv"]
        buf_len = kvc.k.shape[2]
        ring = kvc.ring
        quant = kvc.quantized

        def body(carry, inp):
            x, aux = carry
            if quant:
                lp, kb, vb, ksc, vsc = inp
            else:
                lp, kb, vb = inp
            x, (k, v), a = _dense_body(cfg, attn_impl, moe_impl, lp, x,
                                       cos_sin)
            if quant:
                k, ks = L.quantize_kv(k)
                v, vs = L.quantize_kv(v)
            if ring:
                # ring prefill: only the last `take` tokens fit the window;
                # write them at their absolute-position slots (pos % buf_len)
                take = min(s, buf_len)
                slots = (jnp.arange(s - take, s)) % buf_len
                kb = kb.at[:, slots].set(k[:, -take:])
                vb = vb.at[:, slots].set(v[:, -take:])
                if quant:
                    ksc = ksc.at[:, slots].set(ks[:, -take:])
                    vsc = vsc.at[:, slots].set(vs[:, -take:])
            else:
                kb = jax.lax.dynamic_update_slice(kb, k, (0, 0, 0, 0))
                vb = jax.lax.dynamic_update_slice(vb, v, (0, 0, 0, 0))
                if quant:
                    ksc = jax.lax.dynamic_update_slice(ksc, ks, (0, 0))
                    vsc = jax.lax.dynamic_update_slice(vsc, vs, (0, 0))
            if quant:
                return (x, aux + a), (kb, vb, ksc, vsc)
            return (x, aux + a), (kb, vb)

        if quant:
            (h, aux), (knew, vnew, ksnew, vsnew) = layer_scan(
                body, (h, jnp.float32(0)),
                (params["layers"], kvc.k, kvc.v, kvc.k_scale, kvc.v_scale),
            )
            cache = dict(cache)
            cache["kv"] = KVCache(knew, vnew, ring, ksnew, vsnew)
        else:
            (h, aux), (knew, vnew) = layer_scan(
                body, (h, jnp.float32(0)), (params["layers"], kvc.k, kvc.v)
            )
            cache = dict(cache)
            cache["kv"] = KVCache(knew, vnew, ring)
    elif cfg.family == "ssm":
        def body(x, lp):
            hh = L.apply_norm(cfg, lp["norm"], x)
            out, state = S.ssm_forward(lp["ssm"], cfg, hh, impl=attn_impl,
                                       return_state=True)
            return x + out, state

        h, states = layer_scan(body, h, params["layers"])
        cache = dict(cache)
        cache["ssm"] = states
        aux = jnp.float32(0)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        kvc = cache["kv"]
        buf_len = kvc.k.shape[2]
        ring = kvc.ring

        def inner(x, lp):
            hh = L.apply_norm(cfg, lp["norm"], x)
            out, state = S.ssm_forward(lp["ssm"], cfg, hh, impl=attn_impl,
                                       return_state=True)
            return x + out, state

        def group(x, inp):
            gp, kb, vb = inp
            x, gstates = layer_scan(inner, x, gp)
            x, (k, v), _ = _dense_body(cfg, attn_impl, moe_impl, shared, x,
                                       cos_sin)
            if ring:
                take = min(s, buf_len)
                slots = (jnp.arange(s - take, s)) % buf_len
                kb = kb.at[:, slots].set(k[:, -take:])
                vb = vb.at[:, slots].set(v[:, -take:])
            else:
                kb = jax.lax.dynamic_update_slice(kb, k, (0, 0, 0, 0))
                vb = jax.lax.dynamic_update_slice(vb, v, (0, 0, 0, 0))
            return x, (gstates, kb, vb)

        h, (gstates, knew, vnew) = layer_scan(
            group, h, (params["groups"], kvc.k, kvc.v)
        )
        cache = dict(cache)
        cache["groups_ssm"] = gstates
        cache["kv"] = KVCache(knew, vnew, ring)
        if "tail" in params:
            h, tstates = layer_scan(inner, h, params["tail"])
            cache["tail_ssm"] = tstates
        aux = jnp.float32(0)
    elif cfg.family == "audio":
        enc_out = encode_audio(params, cfg, batch["frames"], attn_impl)
        kvc = cache["kv"]

        def body(x, inp):
            lp, kb, vb = inp
            hh = L.apply_norm(cfg, lp["attn_norm"], x)
            attn_out, (k, v) = L.attention_block(lp["attn"], cfg, hh, None,
                                                 attn_impl=attn_impl)
            x = x + attn_out
            kb = jax.lax.dynamic_update_slice(kb, k, (0, 0, 0, 0))
            vb = jax.lax.dynamic_update_slice(vb, v, (0, 0, 0, 0))
            hh = L.apply_norm(cfg, lp["cross_norm"], x)
            ck, cv = L.encode_cross_kv(lp["cross"], cfg, enc_out)
            x = x + L.cross_attention_block(lp["cross"], cfg, hh, (ck, cv))
            hh = L.apply_norm(cfg, lp["mlp_norm"], x)
            return x + L.mlp_block(lp["mlp"], cfg, hh), (kb, vb, ck, cv)

        h, (knew, vnew, ck, cv) = layer_scan(
            body, h, (params["layers"], kvc.k, kvc.v)
        )
        cache = dict(cache)
        cache["kv"] = KVCache(knew, vnew)
        cache["cross_kv"] = KVCache(ck, cv)
        aux = jnp.float32(0)
    cache["len"] = jnp.full((h.shape[0],), s, jnp.int32)
    if last_index is not None:
        hsel = h[jnp.arange(h.shape[0]), last_index][:, None, :]
    else:
        hsel = h[:, -1:, :]
    logits = unembed(params, cfg, hsel)
    return logits, cache


#: families :func:`prefill_chunk` supports — attention-only stacks whose KV
#: writes are position-addressable.  Recurrent state (ssm/hybrid) absorbs
#: every position it sees, and audio carries encoder cross-KV seeded by the
#: one-shot path; both keep exact one-shot prefill.
CHUNKABLE_FAMILIES = ("dense", "moe", "vlm")


def prefill_chunk(params: Params, cfg, batch: Dict, cache: Cache,
                  *, attn_impl: str = "xla", moe_impl: str = "dense",
                  start, valid_len):
    """Process ONE prompt chunk against a partially-filled cache.

    ``batch["tokens"]`` is (B, C) — C chunk tokens (right-padded to a shape
    bucket), of which the first ``valid_len`` (B,) are real, starting at
    absolute position ``start`` (B,) = tokens already prefilled.  The chunk's
    K/V are span-written into the cache at ``[start, start + valid_len)``
    and its queries attend over the whole buffer under a ``kv_len`` mask, so
    running a prompt as chunks is **bit-identical** to :func:`prefill` (see
    ``layers.attention_block``).  Returns logits at the chunk's last valid
    position (B, 1, V) — the caller samples the first output token from the
    final chunk's logits, exactly as it does from one-shot prefill's.

    Only :data:`CHUNKABLE_FAMILIES` with dense unquantized KV caches are
    supported; callers fall back to one-shot prefill otherwise.
    """
    if cfg.family not in CHUNKABLE_FAMILIES:
        raise ValueError(
            f"prefill_chunk supports families {CHUNKABLE_FAMILIES}, "
            f"got {cfg.family!r} — use one-shot prefill")
    tokens = batch["tokens"]
    b, c = tokens.shape
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,))
    valid = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    h, pos = embed_inputs(params, cfg, {**batch, "positions": pos})
    cos_sin = (L.positional_cos_sin(cfg, pos)
               if cfg.rope_type in ("rope", "mrope") else None)
    kvc = cache["kv"]

    def body(carry, inp):
        x, aux = carry
        lp, kb, vb = inp
        x, nkv, a = _dense_body(cfg, attn_impl, moe_impl, lp, x, cos_sin,
                                cache=L.KVCache(kb, vb, kvc.ring),
                                cur_index=start, valid_len=valid)
        return (x, aux + a), (nkv.k, nkv.v)

    (h, _), (knew, vnew) = layer_scan(
        body, (h, jnp.float32(0)), (params["layers"], kvc.k, kvc.v)
    )
    new_cache = dict(cache)
    new_cache["kv"] = KVCache(knew, vnew, kvc.ring)
    new_cache["len"] = start + valid
    hsel = h[jnp.arange(b), valid - 1][:, None, :]
    return unembed(params, cfg, hsel), new_cache


# =========================================================================== #
# Decode step
# =========================================================================== #


def decode_step(params: Params, cfg, tokens: jnp.ndarray, cache: Cache,
                *, attn_impl: str = "xla", moe_impl: str = "grouped",
                active: Optional[jnp.ndarray] = None, mesh=None):
    """One-token auto-regressive step.  tokens (B, 1) -> (logits, cache).

    ``active`` (B,) bool — the continuous-batching mask: rows marked
    inactive (unoccupied slots, or slots frozen at EOS mid-window) are
    computed but their cache is left bit-identical — no KV/state write, no
    ``len`` advance — so a statically-shaped batch can carry dead slots
    through a shared dispatch without corrupting them.  ``attn_impl="pallas"``
    routes the attention read through the Pallas flash-decode kernel
    (:mod:`repro.kernels.decode_attention`) with the per-slot ``len`` vector
    as kv lengths; ``"xla"`` is the einsum reference path.

    ``mesh`` — when the caller runs under a TP mesh with head-sharded KV
    (``kv_shard="heads"``), passing the mesh routes the Pallas read through
    the ``shard_map``-wrapped kernel so each shard attends over its local
    heads (DESIGN.md §11).  Only valid for layouts where the head axes
    divide the ``"model"`` mesh axis — the engine gates this via
    :func:`repro.launch.partition.pallas_decode_support`.
    """
    b = tokens.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cache["len"]), (b,))  # per-slot lengths
    h = params["embed"][tokens]
    pos = cur[:, None]  # (B, 1)
    if cfg.rope_type == "learned":
        safe = jnp.minimum(cur, cfg.max_position_embeddings - 1)
        h = h + params["pos_embed"][safe][:, None, :]
    cos_sin = (
        L.positional_cos_sin(cfg, pos)
        if cfg.rope_type in ("rope", "mrope")
        else None
    )
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm"):
        kvc = cache["kv"]
        ring = kvc.ring
        quant = kvc.quantized

        def body(carry, inp):
            x, aux = carry
            if quant:
                lp, kb, vb, ksc, vsc = inp
                lc = KVCache(kb, vb, ring, ksc, vsc)
            else:
                lp, kb, vb = inp
                lc = KVCache(kb, vb, ring)
            x, nkv, a = _dense_body(cfg, attn_impl, moe_impl, lp, x, cos_sin,
                                    cache=lc, cur_index=cur, active=active,
                                    mesh=mesh)
            if quant:
                return (x, aux + a), (nkv.k, nkv.v, nkv.k_scale, nkv.v_scale)
            return (x, aux + a), (nkv.k, nkv.v)

        if quant:
            (h, _), (knew, vnew, ksnew, vsnew) = layer_scan(
                body, (h, jnp.float32(0)),
                (params["layers"], kvc.k, kvc.v, kvc.k_scale, kvc.v_scale),
            )
            new_cache["kv"] = KVCache(knew, vnew, ring, ksnew, vsnew)
        else:
            (h, _), (knew, vnew) = layer_scan(
                body, (h, jnp.float32(0)), (params["layers"], kvc.k, kvc.v)
            )
            new_cache["kv"] = KVCache(knew, vnew, ring)
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            x, nst = _ssm_body(cfg, attn_impl, lp, x, state=st, active=active)
            return x, nst

        h, nstates = layer_scan(body, h, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = nstates
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        kvc = cache["kv"]
        ring = kvc.ring

        def inner(x, inp):
            lp, st = inp
            x, nst = _ssm_body(cfg, attn_impl, lp, x, state=st, active=active)
            return x, nst

        def group(x, inp):
            gp, gst, kb, vb = inp
            x, ngst = layer_scan(inner, x, (gp, gst))
            x, nkv, _ = _dense_body(cfg, attn_impl, moe_impl, shared, x,
                                    cos_sin, cache=KVCache(kb, vb, ring),
                                    cur_index=cur, active=active, mesh=mesh)
            return x, (ngst, nkv.k, nkv.v)

        h, (ngroups, knew, vnew) = layer_scan(
            group, h,
            (params["groups"], cache["groups_ssm"], kvc.k, kvc.v),
        )
        new_cache["groups_ssm"] = ngroups
        new_cache["kv"] = KVCache(knew, vnew, ring)
        if "tail_ssm" in cache:
            h, ntail = layer_scan(inner, h,
                                    (params["tail"], cache["tail_ssm"]))
            new_cache["tail_ssm"] = ntail
    elif cfg.family == "audio":
        kvc = cache["kv"]
        cross = cache["cross_kv"]

        def body(x, inp):
            lp, kb, vb, ck, cv = inp
            hh = L.apply_norm(cfg, lp["attn_norm"], x)
            attn_out, nkv = L.attention_block(
                lp["attn"], cfg, hh, None, cache=KVCache(kb, vb),
                cur_index=cur, attn_impl=attn_impl, active=active, mesh=mesh,
            )
            x = x + attn_out
            hh = L.apply_norm(cfg, lp["cross_norm"], x)
            x = x + L.cross_attention_block(lp["cross"], cfg, hh, (ck, cv))
            hh = L.apply_norm(cfg, lp["mlp_norm"], x)
            return x + L.mlp_block(lp["mlp"], cfg, hh), (nkv.k, nkv.v)

        h, (knew, vnew) = layer_scan(
            body, h,
            (params["layers"], kvc.k, kvc.v, cross.k, cross.v),
        )
        new_cache["kv"] = KVCache(knew, vnew)
    else:
        raise ValueError(cfg.family)

    if active is not None:
        new_cache["len"] = jnp.where(active, cur + 1, cur)
    else:
        new_cache["len"] = cur + 1
    return unembed(params, cfg, h), new_cache


# =========================================================================== #
# Abstract params (for dry-run lowering without allocation)
# =========================================================================== #


def abstract_params(cfg) -> Params:
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )


def abstract_cache(cfg, batch: int, max_len: int,
                   sliding_window: Optional[int] = None,
                   kv_dtype: Optional[str] = None) -> Cache:
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len,
                          sliding_window=sliding_window, kv_dtype=kv_dtype)
    )
