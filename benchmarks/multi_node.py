"""Cluster-scheduling sweep: n_nodes x placement x rebalancing.

ELIS deploys as a multi-worker system (paper §4.1): the frontend consults
global state G and load-balances requests across pods.  This benchmark
quantifies what the prediction-aware cluster layer adds, in two scenarios
that separate *placement* gains from *ordering* gains:

* ``ordering=fcfs`` — per-node FCFS continuous batching (ORCA-style, no
  reordering), flash-crowd bursts: the regime of Qiu et al.'s proxy-model
  placement, where the response-length predictor is consulted ONLY at
  placement.  Splitting a burst by predicted work instead of job count is
  the headline win (``least_predicted_work`` < ``least_jobs``), asserted
  on heterogeneous clusters for every n_nodes.
* ``ordering=isrtf`` — the paper's in-node scheduler already reorders by
  predicted remaining length, which recaptures most placement slack
  (count-based placement feeds off queue-length feedback); what is left on
  a heterogeneous cluster is pod *speed*, which only ``least_eta`` sees
  (per-node token costs + the live ``busy_until`` horizon) — asserted to
  beat ``least_jobs`` there.

Clusters: uniform (all ``vic``) vs heterogeneous (fast ``vic`` pods mixed
with slow ``lam13`` — node ids divisible by 3 are fast, so 1 fast / 1 slow
at n_nodes=2 and 2/2 at n_nodes=4; both profiles calibrated from paper
Table 4, ~2.9x decode spread).  Cross-node rebalancing (work-stealing of
queued jobs) is swept on/off in every cell; migration counts are reported.

Emits ``BENCH_multi_node.json`` at the repo root (committed) with mean/p99
JCT and migration counts per cell.  ``--smoke`` runs a reduced sweep with
the same assertions as a CI guard against placement regressions.

    PYTHONPATH=src python -m benchmarks.multi_node [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.simulate import ExperimentConfig, run_experiment

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_multi_node.json")

#: heterogeneous pod mix: fast ``vic`` pods (node ids divisible by 3)
#: among slow ``lam13`` pods (Table-4 calibrated; ~2.9x decode spread)
FAST, SLOW = "vic", "lam13"

PLACEMENTS = ("least_jobs", "least_predicted_work", "least_eta")

#: flash-crowd size for the bursty scenario — large enough that a burst
#: splits across every node (transient skew is what placement must absorb)
BURST = 24


def hetero_map(n_nodes: int) -> Dict[int, str]:
    return {n: (FAST if n % 3 == 0 else SLOW) for n in range(n_nodes)}


def one_cell(n_nodes: int, placement: str, rebalance: bool, cluster: str,
             ordering: str, n_requests: int, seeds: List[int]) -> Dict:
    """One sweep cell, averaged over seeds (arrival times + workload)."""
    arrivals = "bursty" if ordering == "fcfs" else "gamma"
    jct_mean, jct_p99, migr = [], [], []
    for seed in seeds:
        cfg = ExperimentConfig(
            model=FAST, policy=ordering, predictor="oracle",
            n_requests=n_requests, n_nodes=n_nodes, batch_size=4,
            rps_multiple=1.2, seed=seed,
            placement=placement, rebalance=rebalance,
            node_profiles=hetero_map(n_nodes) if cluster == "hetero" else None,
            arrivals=arrivals, burst_size=BURST,
        )
        # streaming aggregation: responses are consumed as they finish, so
        # peak memory stays flat however large the sweep cell grows (means
        # exact; p99 within the quantile sketch's ~0.3% tolerance)
        m = run_experiment(cfg, stream_metrics=True)
        assert m["n_unfinished"] == 0, m
        jct_mean.append(m["jct_mean"])
        jct_p99.append(m["jct_p99"])
        migr.append(m["migrations"])
    return {
        "cluster": cluster,
        "ordering": ordering,
        "arrivals": arrivals,
        "n_nodes": n_nodes,
        "placement": placement,
        "rebalance": rebalance,
        "n_requests": n_requests,
        "seeds": seeds,
        "jct_mean": round(float(np.mean(jct_mean)), 3),
        "jct_p99": round(float(np.mean(jct_p99)), 3),
        "migrations": round(float(np.mean(migr)), 1),
    }


def cell(rows: List[Dict], **want) -> Optional[Dict]:
    for r in rows:
        if all(r[k] == v for k, v in want.items()):
            return r
    return None


def run(smoke: bool = False, quick: bool = False) -> List[Dict]:
    smoke = smoke or quick  # benchmarks.run harness passes quick=
    if smoke:
        node_counts, n_requests, seeds = [2], 120, [0, 1]
        clusters = ["hetero"]
    else:
        node_counts, n_requests, seeds = [2, 4], 160, [0, 1, 2, 3]
        clusters = ["uniform", "hetero"]

    rows: List[Dict] = []
    for cluster in clusters:
        for ordering in ("fcfs", "isrtf"):
            for n_nodes in node_counts:
                for placement in PLACEMENTS:
                    for rebalance in (False, True):
                        rows.append(one_cell(n_nodes, placement, rebalance,
                                             cluster, ordering, n_requests,
                                             seeds))
                        print(rows[-1])

    # headline guarantees the committed JSON documents
    for n_nodes in node_counts:
        # 1. prediction-aware placement beats the job counter where the
        #    in-node scheduler does not reorder (FCFS pods, bursty load)
        lj = cell(rows, cluster="hetero", ordering="fcfs", n_nodes=n_nodes,
                  placement="least_jobs", rebalance=False)
        lpw = cell(rows, cluster="hetero", ordering="fcfs", n_nodes=n_nodes,
                   placement="least_predicted_work", rebalance=False)
        assert lpw["jct_mean"] < lj["jct_mean"], (
            "length-weighted placement must strictly improve mean JCT over "
            f"the job counter on a heterogeneous cluster: {lpw} vs {lj}")
        # 2. under ISRTF ordering, the speed-aware least_eta policy is what
        #    protects the tail on a heterogeneous cluster (count-based
        #    placement strands long jobs on slow pods)
        lj_i = cell(rows, cluster="hetero", ordering="isrtf",
                    n_nodes=n_nodes, placement="least_jobs", rebalance=False)
        eta_i = cell(rows, cluster="hetero", ordering="isrtf",
                     n_nodes=n_nodes, placement="least_eta", rebalance=False)
        assert eta_i["jct_p99"] < lj_i["jct_p99"], (
            f"least_eta must beat least_jobs p99 on hetero: "
            f"{eta_i} vs {lj_i}")
    # 3. rebalancing actually migrates work when enabled
    reb = [r for r in rows if r["rebalance"] and r["cluster"] == "hetero"]
    assert any(r["migrations"] > 0 for r in reb), reb

    save_results("multi_node", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep, assertions only (CI placement guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(smoke=args.smoke and not args.full)
    if not args.smoke:
        # regenerate the committed evidence only on a deliberate CLI run
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    for n_nodes in sorted({r["n_nodes"] for r in rows}):
        lj = cell(rows, cluster="hetero", ordering="fcfs", n_nodes=n_nodes,
                  placement="least_jobs", rebalance=False)
        lpw = cell(rows, cluster="hetero", ordering="fcfs", n_nodes=n_nodes,
                   placement="least_predicted_work", rebalance=False)
        gain = 100 * (lj["jct_mean"] - lpw["jct_mean"]) / lj["jct_mean"]
        print(f"[multi_node] hetero fcfs n={n_nodes}: least_jobs "
              f"{lj['jct_mean']:.2f}s -> least_predicted_work "
              f"{lpw['jct_mean']:.2f}s ({gain:.1f}% better)")


if __name__ == "__main__":
    main()
