"""Benchmark harness — one function per paper table/figure.

``python -m benchmarks.run [--full]`` executes every benchmark and prints a
``name,us_per_call,derived`` CSV line per benchmark (us_per_call = wall time
of the benchmark itself; derived = its headline result).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size runs (default: quick)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        ablations,
        appendixA_preemption,
        fig1_embedding,
        fig2_iterative_mae,
        fig4_arrivals,
        fig6_batch_sizes,
        fig7_scalability,
        live_engine,
        multi_device,
        multi_node,
        predictor_calibration,
        prefill_preempt,
        rank_sched,
        roofline,
        scheduler_overhead,
        sim_scale,
        table2_predictor,
        table5_jct,
    )

    benches = [
        ("fig1_embedding", fig1_embedding.run,
         lambda rows: f"separation_ratio={rows[0]['separation_ratio']}"),
        ("fig4_arrivals", fig4_arrivals.run,
         lambda rows: f"gamma_fits_better={rows[0]['gamma_fits_better']};"
                      f"alpha={rows[0]['fit_alpha']}"),
        ("table2_predictor", table2_predictor.run,
         lambda rows: f"r2_untrained={rows[0]['r2']:.2f};"
                      f"r2_trained={rows[1]['r2']:.2f};"
                      f"mae_trained={rows[1]['mae']:.1f}"),
        ("fig2_iterative_mae", fig2_iterative_mae.run,
         lambda rows: "mae_by_step=" + "/".join(
             f"{r['mae']:.0f}" for r in rows)),
        ("scheduler_overhead", scheduler_overhead.run,
         lambda rows: "isrtf_one_dispatch_per_window=" + str(all(
             r["dispatches"] == r["windows"] for r in rows
             if r["policy"] == "isrtf" and r["repredict_every"] == 1))
         + ";max_traces=" + str(max(r.get("num_traces", 0) for r in rows))),
        ("table5_jct", table5_jct.run,
         lambda rows: f"mean_isrtf_gain_pct={sum(r['isrtf_vs_fcfs_pct'] for r in rows)/len(rows):.1f}"),
        ("predictor_calibration", predictor_calibration.run,
         lambda rows: "ema_bias=" + str(predictor_calibration.cell(
             rows, regime="biased_oracle", calibrate="ema",
             risk_quantile=None)["pred_bias"])
         + ";coverage_q0.9=" + str(rows[0].get("coverage_q0.9"))),
        ("rank_sched", rank_sched.run,
         lambda rows: f"tau_regression={rows[0]['tau_regression']};"
                      f"tau_rank={rows[0]['tau_rank']};"
                      f"rank_isrtf_jct={rank_sched.cell(rows, predictor='ranked', policy='isrtf', calibrate='none')['jct_mean']}"),
        ("multi_node", multi_node.run,
         lambda rows: "hetero_fcfs_lpw_gain_pct=" + "/".join(
             f"{100 * (1 - multi_node.cell(rows, cluster='hetero', ordering='fcfs', n_nodes=n, placement='least_predicted_work', rebalance=False)['jct_mean'] / multi_node.cell(rows, cluster='hetero', ordering='fcfs', n_nodes=n, placement='least_jobs', rebalance=False)['jct_mean']):.1f}"
             for n in sorted({r["n_nodes"] for r in rows}))),
        ("fig6_batch_sizes", fig6_batch_sizes.run,
         lambda rows: f"max_gain_pct={max(r['improvement_pct'] for r in rows):.1f}"),
        ("fig7_scalability", fig7_scalability.run,
         lambda rows: f"peak_rps@{rows[-2]['n_workers']}w={rows[-2]['peak_rps']}"),
        ("appendixA_preemption", appendixA_preemption.run,
         lambda rows: f"onset_within_2x={sum(1 for r in rows if r.get('within_2x_of_paper'))}/5"),
        ("live_engine", live_engine.run,
         lambda rows: "live_gain_pct=" + str(next(
             r["live_isrtf_vs_fcfs_improvement_pct"] for r in rows
             if "live_isrtf_vs_fcfs_improvement_pct" in r))
         + ";live_vs_sim_ratio=" + str(next(
             r["calibration"]["live_vs_sim_ratio"] for r in rows
             if "calibration" in r))),
        ("multi_device", multi_device.run,
         # tolerant: on a 1-device host the bench returns a skip note
         lambda rows: rows[0].get("note") or (
             "live_vs_sim_ratio=" + str(next(
                 r["sim_replay"]["live_vs_sim_ratio"] for r in rows
                 if "sim_replay" in r))
             + ";eta_jct_s=" + str(next(
                 r["jct_mean_s"] for r in rows
                 if r.get("placement") == "least_eta")))),
        ("prefill_preempt", prefill_preempt.run,
         lambda rows: "chunk_jct_ratio=" + str(min(
             r["jct_vs_unchunked"] for r in rows
             if r["regime"] == "mixed_prompts"
             and r["prefill_chunk"] is not None))
         + ";auto_vs_recompute=" + str(min(
             r["jct_vs_recompute"] for r in rows
             if r.get("preempt_policy") == "auto"))),
        ("sim_scale", sim_scale.run,
         lambda rows: f"requests_per_s={rows[0]['requests_per_s']};"
                      f"peak_rss_mb={rows[0]['peak_rss_mb']};"
                      f"trace_identical={rows[-1]['trace_identical']}"),
        ("ablations", ablations.run,
         lambda rows: "mlfq_gain_pct=" + str(next(
             (r["gain_vs_fcfs_pct"] for r in rows
              if r.get("ablation") == "mlfq_comparison"
              and r.get("policy") == "mlfq"), "?")) + ";sigma_sweep=" + "/".join(
             f"{r['gain_vs_fcfs_pct']:.0f}" for r in rows
             if r.get("ablation") == "predictor_quality" and "sigma_rel" in r)),
        ("roofline", roofline.run,
         lambda rows: f"pairs={len(rows)};"
                      f"collective_bound={sum(1 for r in rows if r['dominant']=='collective')};"
                      f"memory_bound={sum(1 for r in rows if r['dominant']=='memory')}"),
    ]

    print("name,us_per_call,derived")
    for name, fn, derive in benches:
        if args.only and args.only != name:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn(quick=quick)
            derived = derive(rows) if rows else "no-results"
        except Exception as e:  # noqa: BLE001
            derived = f"ERROR:{e!r}"
            rows = []
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
