"""Paper Fig. 4: inter-arrival intervals follow Gamma(α=0.73, β=10.41),
fitting better than a Poisson (exponential-interval) process."""
from __future__ import annotations

import numpy as np

from repro.data import (
    FABRIX_ALPHA,
    FABRIX_SCALE,
    GammaArrivals,
    exponential_loglik,
    fit_gamma,
    gamma_loglik,
)

from benchmarks.common import save_results


def run(quick: bool = False):
    n = 50_000 if not quick else 10_000
    rng = np.random.RandomState(0)
    iv = GammaArrivals().sample_intervals(n, rng)
    a, s = fit_gamma(iv)
    ll_gamma = gamma_loglik(iv, a, s)
    ll_exp = exponential_loglik(iv)
    rows = [{
        "n_intervals": n,
        "true_alpha": FABRIX_ALPHA,
        "true_scale": FABRIX_SCALE,
        "fit_alpha": round(a, 4),
        "fit_scale": round(s, 3),
        "loglik_gamma": round(ll_gamma, 1),
        "loglik_poisson": round(ll_exp, 1),
        "gamma_fits_better": ll_gamma > ll_exp,
        "delta_aic": round(2 * (ll_gamma - ll_exp) - 2, 1),
    }]
    save_results("fig4_arrivals", rows)
    return rows


if __name__ == "__main__":
    print(run(quick=True))
