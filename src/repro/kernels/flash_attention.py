"""Pallas flash-attention (prefill/training) kernel for TPU.

Blocked online-softmax attention with explicit VMEM tiling:
  grid = (batch, heads, num_q_blocks, num_kv_blocks) — the trailing KV axis
  iterates sequentially on TPU, so the running (m, l, acc) statistics live in
  VMEM scratch and persist across KV steps (the canonical Mosaic pattern).

Supports GQA (kv-head index derived statically from the query head), causal
masking with a query offset, and sliding-window (SWA) masking.  Block sizes
default to 128×128 — MXU-aligned on the (sublane, lane) = (8, 128) layout.

Validated on CPU in ``interpret=True`` mode against ``ref.reference_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (BK, D)

    s = jnp.dot(q, k.T) * scale  # (BQ, BK)

    qi = pl.program_id(2)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KH, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    assert h % kh == 0
    rep = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    n_q = sq // block_q
    n_k = skv // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    grid = (b, h, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // rep, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
