"""Strategies for the hypothesis shim — random draws, no shrinking.

Each strategy exposes ``example(rng)`` drawing one value from a
``random.Random`` instance owned by ``@given``.
"""
from __future__ import annotations

import random
from typing import Callable, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], object]):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive for shim")

        return SearchStrategy(draw)


def integers(min_value: int = 0, max_value: int = 1 << 16) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def characters(min_codepoint: int = 32, max_codepoint: int = 126,
               **_) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: chr(rng.randint(min_codepoint, max_codepoint)))


def text(alphabet: SearchStrategy = None, min_size: int = 0,
         max_size: int = 20) -> SearchStrategy:
    alpha = alphabet if alphabet is not None else characters()
    return SearchStrategy(lambda rng: "".join(
        alpha.example(rng)
        for _ in range(rng.randint(min_size, max_size))))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 20) -> SearchStrategy:
    return SearchStrategy(lambda rng: [
        elements.example(rng)
        for _ in range(rng.randint(min_size, max_size))])


def sampled_from(options: Sequence) -> SearchStrategy:
    opts = list(options)
    return SearchStrategy(lambda rng: opts[rng.randrange(len(opts))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: strategies[rng.randrange(len(strategies))].example(rng))


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies))
