"""Live JAX engine: greedy exactness, windows, preemption resume, the
fast path (batched bucketed prefill, masked/compacted decode, Pallas
decode attention), and slot-bookkeeping properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core import Job
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine, SamplerConfig
from repro.models import forward, init_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n):
    """Naive greedy decode via repeated full forward (the oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n):
        logits, _ = forward(params, cfg, {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_greedy_matches_forward(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1,
        sampler=SamplerConfig(temperature=0.0)))
    job = Job(job_id=0, prompt="x", prompt_tokens=[11, 22, 33, 44],
              arrival_time=0.0)
    toks, fin = eng.run_window([job], 10)
    want = greedy_reference(cfg, params, [11, 22, 33, 44], 10)
    assert toks[0] == want


def test_engine_windows_continue_exactly(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1))
    job = Job(job_id=1, prompt="x", prompt_tokens=[5, 6, 7], arrival_time=0.0)
    t1, _ = eng.run_window([job], 6)
    job.generated.extend(t1[0])
    t2, _ = eng.run_window([job], 6)
    want = greedy_reference(cfg, params, [5, 6, 7], 12)
    assert t1[0] + t2[0] == want


def test_preempt_resume_is_exact(setup):
    """Evict + recompute-resume must continue the identical greedy stream."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=128, max_output=64, eos_id=-1))
    job = Job(job_id=2, prompt="x", prompt_tokens=[9, 8, 7], arrival_time=0.0)
    t1, _ = eng.run_window([job], 5)
    job.generated.extend(t1[0])
    eng.evict_job(job.job_id)          # preemption
    assert eng.free_slots() == 1
    t2, _ = eng.run_window([job], 5)   # recompute-resume
    job.generated.extend(t2[0])
    want = greedy_reference(cfg, params, [9, 8, 7], 10)
    assert job.generated == want
    assert job.generated[:5] == t1[0]


def test_two_slots_independent(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1))
    j0 = Job(job_id=3, prompt="a", prompt_tokens=[1, 2, 3], arrival_time=0.0)
    j1 = Job(job_id=4, prompt="b", prompt_tokens=[4, 5, 6, 7, 8],
             arrival_time=0.0)
    toks, _ = eng.run_window([j0, j1], 8)
    assert toks[0] == greedy_reference(cfg, params, [1, 2, 3], 8)
    assert toks[1] == greedy_reference(cfg, params, [4, 5, 6, 7, 8], 8)


def test_eos_truncates_and_finishes(setup):
    cfg, params = setup
    # find the first greedy token and use it as the EOS id -> finishes at once
    first = greedy_reference(cfg, params, [11, 22, 33, 44], 1)[0]
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=128, max_output=64, eos_id=first))
    job = Job(job_id=5, prompt="x", prompt_tokens=[11, 22, 33, 44],
              arrival_time=0.0)
    toks, fin = eng.run_window([job], 10)
    assert fin[0] and toks[0] == [first]


def test_executor_capacity_guard(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(max_slots=1, max_len=128))
    ex = EngineExecutor({0: eng})
    jobs = [Job(job_id=i + 10, prompt="x", prompt_tokens=[1, 2],
                arrival_time=0.0) for i in range(2)]
    with pytest.raises(RuntimeError):
        ex.execute(0, jobs, 5, 0.0)


# =========================================================================== #
# Fast path: batched bucketed prefill + masked (compacted) decode
# =========================================================================== #


def _mk(i, toks):
    return Job(job_id=i, prompt=f"p{i}", prompt_tokens=list(toks),
               arrival_time=0.0)


def test_batched_prefill_matches_serial(setup):
    """One (batch, seq)-bucketed prefill dispatch == N batch-1 dispatches."""
    cfg, params = setup
    base = dict(max_slots=4, max_len=128, max_output=64, eos_id=-1)
    prompts = [[11, 22, 33, 44], [5, 6, 7], [9, 8, 7, 6, 5],
               [1, 2, 3, 4, 5, 6, 7]]
    eb = InferenceEngine(cfg, params, EngineConfig(batched_prefill=True,
                                                   **base))
    es = InferenceEngine(cfg, params, EngineConfig(
        batched_prefill=False, masked_decode=False, **base))
    tb, fb = eb.run_window([_mk(i, p) for i, p in enumerate(prompts)], 8)
    ts, fs = es.run_window([_mk(i, p) for i, p in enumerate(prompts)], 8)
    assert tb == ts and fb == fs
    assert eb.num_prefill_dispatches == 1
    assert es.num_prefill_dispatches == len(prompts)
    assert np.array_equal(np.asarray(eb.cache["len"]),
                          np.asarray(es.cache["len"]))


def test_prefill_compiles_once_per_bucket(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=4, max_len=128, max_output=64, eos_id=-1))
    eng.add_jobs([_mk(0, range(4)), _mk(1, range(6))])     # (2, 16)
    eng.add_jobs([_mk(2, range(20))])                      # (1, 32)
    assert eng.num_prefill_traces == 2
    eng.evict_job(2)
    eng.add_jobs([_mk(3, range(18))])                      # (1, 32) again
    assert eng.num_prefill_traces == 2, "same bucket retraced"
    assert eng.num_prefill_traces <= eng.prefill_shape_bound()
    with pytest.raises(ValueError):
        eng.add_jobs([_mk(9, range(300))])                 # > max_len


def test_add_job_on_full_engine_raises_before_dispatch(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=64, max_output=64, eos_id=-1))
    slot = eng.add_job(_mk(0, [1, 2, 3]))
    assert eng.add_job(_mk(0, [1, 2, 3])) == slot  # idempotent re-admit
    dispatches = eng.num_prefill_dispatches
    with pytest.raises(RuntimeError, match="free slots"):
        eng.add_job(_mk(1, [4, 5, 6]))
    assert eng.num_prefill_dispatches == dispatches  # no wasted prefill


def test_masked_decode_compacts_to_bucket(setup):
    """Decode dispatches are shaped by the *scheduled* batch bucket, not
    max_slots, and one compiled shape serves repeated windows."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=4, max_len=128, max_output=64, eos_id=-1))
    jobs = [_mk(0, [11, 22, 33]), _mk(1, [5, 6, 7])]
    eng.run_window(jobs, 4)
    assert (4, 2) in eng._window_cache and (4, 4) not in eng._window_cache
    eng.run_window(jobs, 4)
    assert eng.num_decode_dispatches == 2
    assert eng.num_decode_traces == 1


def test_unscheduled_slot_is_frozen_and_resumes_exactly(setup):
    """An occupied slot left out of the scheduled batch must be untouched
    by the dispatch (no stale-KV corruption) and continue bit-exactly."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=128, max_output=64, eos_id=-1))
    j0, j1 = _mk(0, [11, 22, 33, 44]), _mk(1, [5, 6, 7])
    t1, _ = eng.run_window([j0, j1], 6)
    j0.generated.extend(t1[0])
    j1.generated.extend(t1[1])
    s0 = eng.slot_of[0]
    len_before = int(np.asarray(eng.cache["len"])[s0])
    t2, _ = eng.run_window([j1], 5)       # j0 occupied but NOT scheduled
    j1.generated.extend(t2[0])
    assert int(np.asarray(eng.cache["len"])[s0]) == len_before
    t3, _ = eng.run_window([j0], 6)       # j0 continues from frozen cache
    j0.generated.extend(t3[0])
    assert j0.generated == greedy_reference(cfg, params, [11, 22, 33, 44], 12)
    assert j1.generated == greedy_reference(cfg, params, [5, 6, 7], 11)


def test_preempt_resume_with_slot_recycling(setup):
    """Evict + re-add recomputes from the preserved partial output even
    after ANOTHER job has decoded in the recycled slot (stale KV would
    corrupt the stream if resume didn't recompute)."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=1, max_len=128, max_output=64, eos_id=-1))
    victim = _mk(0, [9, 8, 7])
    t1, _ = eng.run_window([victim], 5)
    victim.generated.extend(t1[0])
    eng.evict_job(0)                       # preemption
    thief = _mk(1, [1, 2, 3, 4])
    t2, _ = eng.run_window([thief], 7)     # recycles the slot
    thief.generated.extend(t2[0])
    eng.evict_job(1)
    t3, _ = eng.run_window([victim], 5)    # recompute-resume
    victim.generated.extend(t3[0])
    assert victim.generated == greedy_reference(cfg, params, [9, 8, 7], 10)
    assert thief.generated == greedy_reference(cfg, params, [1, 2, 3, 4], 7)


def test_pallas_decode_matches_xla(setup):
    """attn_impl="pallas" routes decode through the flash-decode kernel
    against the slot cache; greedy tokens must match the XLA oracle."""
    cfg, params = setup
    outs = {}
    for impl in ("xla", "pallas"):
        eng = InferenceEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=64, max_output=64, eos_id=-1,
            attn_impl=impl))
        outs[impl], _ = eng.run_window(
            [_mk(0, [11, 22, 33, 44]), _mk(1, [5, 6, 7])], 6)
    assert outs["xla"] == outs["pallas"]


# =========================================================================== #
# EngineExecutor: counters + live<->sim calibration
# =========================================================================== #


def test_executor_counters_and_window_log(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=64, max_output=64, eos_id=-1))
    ex = EngineExecutor({0: eng})
    jobs = [_mk(0, [11, 22, 33]), _mk(1, [5, 6, 7])]
    ex.execute(0, jobs, 4, 0.0)
    c = ex.counters()
    assert c["windows_executed"] == 1 and c["decode_dispatches"] == 1
    assert c["prefill_dispatches"] == 1 and c["prefill_traces"] >= 1
    assert ex.window_log[0]["batch"] == 2
    assert ex.window_log[0]["duration_s"] > 0


def test_calibrated_profile_recovers_latency_model(setup):
    """The live->sim fit inverts duration = o + K*d1*(1+slow*(b-1))."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, EngineConfig(max_slots=4, max_len=64))
    ex = EngineExecutor({0: eng})
    o, d1, slow = 0.004, 0.003, 0.1
    for b in (1, 2, 4):
        for w in (4, 8):
            dur = o + w * d1 * (1 + slow * (b - 1))
            for _ in range(3):  # first occurrence per shape is dropped
                ex.window_log.append({"node": 0, "batch": b, "window": w,
                                      "duration_s": dur, "tokens": b * w})
    prof = ex.calibrated_profile(name="fit-test")
    assert abs(prof.decode_ms_1 - d1 * 1000) / (d1 * 1000) < 0.05, prof
    assert abs(prof.batch_slowdown - slow) < 0.02, prof
    assert abs(ex.fit_overhead_s - o) < 5e-4
    assert prof.n_layers == cfg.n_layers


# =========================================================================== #
# Property tests: slot bookkeeping under interleaved add/evict/EOS churn
# =========================================================================== #

_PROP = {}


def _prop_engine():
    """One shared engine for the property suite — shapes compile once."""
    if not _PROP:
        cfg = get_config("qwen2-1.5b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg)
        _PROP["cfg"], _PROP["params"] = cfg, params
        _PROP["eng"] = InferenceEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=64, max_output=8, eos_id=-1,
            respect_job_max=True))
        _PROP["greedy"] = {}
    return _PROP["eng"]


def _prop_greedy(prompt, n):
    key = tuple(prompt)
    have = _PROP["greedy"].get(key, [])
    if len(have) < n:
        have = greedy_reference(_PROP["cfg"], _PROP["params"], prompt, n)
        _PROP["greedy"][key] = have
    return have[:n]


def _check_bookkeeping(eng):
    occupied = [s for s, j in enumerate(eng.slot_job) if j is not None]
    assert eng.free_slots() == eng.cfg.max_slots - len(occupied)
    assert sorted(eng.slot_of.values()) == occupied
    for job_id, slot in eng.slot_of.items():
        assert eng.slot_job[slot] == job_id


@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["add", "evict", "run"]),
                          st.integers(0, 7)),
                min_size=4, max_size=12))
def test_slot_bookkeeping_survives_interleaving(ops):
    """free_slots/slot_of stay consistent and every job's emitted stream
    equals the greedy oracle prefix under interleaved add / evict
    (preempt) / run-to-EOS sequences (jobs cap at max_output=8, so EOS-like
    completion and slot recycling happen organically)."""
    eng = _prop_engine()
    # drain anything a previous example left behind
    for jid in list(eng.slot_of):
        eng.evict_job(jid)
    live, done, next_id = {}, [], [1000]
    prompts = [[11, 22, 33], [5, 6, 7, 8], [9, 8, 7], [1, 2, 3, 4, 5]]

    for op, v in ops:
        if op == "add" and eng.free_slots() > 0:
            job = Job(job_id=next_id[0], prompt="p",
                      prompt_tokens=prompts[v % len(prompts)],
                      arrival_time=0.0, true_output_len=4 + v % 5)
            next_id[0] += 1
            eng.add_jobs([job])
            live[job.job_id] = job
        elif op == "evict" and live:
            jid = sorted(live)[v % len(live)]
            eng.evict_job(jid)          # preemption: job keeps its output
        elif op == "run" and live:
            # preempted jobs resume only while slots remain (the frontend's
            # batch formation enforces the same bound via free_capacity)
            holding = [live[j] for j in sorted(live) if eng.has_job(j)]
            slotless = [live[j] for j in sorted(live)
                        if not eng.has_job(j)][: eng.free_slots()]
            batch = holding + slotless
            if not batch:
                continue
            toks, fins = eng.run_window(batch, 2)
            for job, t, fin in zip(batch, toks, fins):
                job.generated.extend(t)
                if fin:
                    eng.evict_job(job.job_id)
                    done.append(live.pop(job.job_id))
        _check_bookkeeping(eng)

    for job in list(live.values()) + done:
        if job.generated:
            want = _prop_greedy(list(job.prompt_tokens), len(job.generated))
            assert list(job.generated) == want, (
                f"job {job.job_id} diverged from the greedy oracle")
