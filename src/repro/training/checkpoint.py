"""Msgpack + numpy checkpointing for arbitrary JAX pytrees.

Layout: a directory per step containing ``tree.msgpack`` (structure +
small leaves) and ``arrays.npz`` (bulk tensors).  Restores to host numpy;
callers re-shard via ``jax.device_put`` with their NamedSharding.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(jax.device_get(tree))
    np.savez(
        os.path.join(tmp, "arrays.npz"),
        **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)},
    )
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(
            {
                "step": step,
                "treedef": str(treedef),
                "n_leaves": len(leaves),
                "metadata": metadata or {},
            },
            f,
        )
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
        and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (shapes validated)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(meta["n_leaves"])]
    t_leaves, treedef = _flatten(target)
    if len(t_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target {len(t_leaves)}"
        )
    for i, (a, b) in enumerate(zip(leaves, t_leaves)):
        if tuple(a.shape) != tuple(np.shape(b)):
            raise ValueError(f"leaf {i} shape {a.shape} != target {np.shape(b)}")
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["metadata"]
