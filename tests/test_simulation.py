"""End-to-end simulator behaviour (the paper's §6 harness in miniature)."""
import numpy as np
import pytest

from repro.core import PreemptionConfig
from repro.simulate import (
    PROFILES,
    ExperimentConfig,
    avg_request_rate,
    compare_policies,
    run_experiment,
)


def test_all_jobs_complete_and_metrics_sane():
    cfg = ExperimentConfig(model="opt6.7", n_requests=60, rps_multiple=1.0,
                           seed=3)
    m = run_experiment(cfg)
    assert m["n"] == 60
    assert m["jct_mean"] > 0
    assert m["queuing_delay_mean"] >= 0
    assert m["jct_p99"] >= m["jct_p50"] >= m["jct_min"] > 0
    assert m["queuing_delay_mean"] < m["jct_mean"]


def test_isrtf_beats_fcfs_under_load():
    """The paper's core claim (Fig. 5/6, up to 19.6%)."""
    base = ExperimentConfig(model="lam13", n_requests=120, rps_multiple=3.0,
                            seed=0)
    res = compare_policies(base, policies=("fcfs", "isrtf", "sjf"), n_trials=2)
    assert res["isrtf"]["jct_mean"] < res["fcfs"]["jct_mean"]
    # SJF with a perfect oracle is the paper's lower bound
    assert res["sjf"]["jct_mean"] <= res["isrtf"]["jct_mean"] * 1.05


def test_gain_comes_from_queuing_delay():
    """Paper §6.2: ISRTF's JCT advantage ≈ its queuing-delay advantage."""
    base = ExperimentConfig(model="lam13", n_requests=120, rps_multiple=3.0,
                            seed=1)
    res = compare_policies(base, policies=("fcfs", "isrtf"), n_trials=2)
    jct_gain = res["fcfs"]["jct_mean"] - res["isrtf"]["jct_mean"]
    q_gain = (res["fcfs"]["queuing_delay_mean"]
              - res["isrtf"]["queuing_delay_mean"])
    assert jct_gain > 0
    # queuing-delay reduction accounts for the bulk of the JCT reduction
    assert q_gain > 0.5 * jct_gain


def test_fcfs_never_preempts():
    cfg = ExperimentConfig(model="opt6.7", policy="fcfs", n_requests=60,
                           rps_multiple=3.0, predictor="none", seed=2,
                           preemption=PreemptionConfig(enabled=False))
    m = run_experiment(cfg)
    assert m["preemptions"] == 0


def test_more_nodes_help():
    slow = run_experiment(ExperimentConfig(model="lam13", n_requests=80,
                                           rps_multiple=2.0, n_nodes=1,
                                           seed=5, rate_override=0.6))
    fast = run_experiment(ExperimentConfig(model="lam13", n_requests=80,
                                           rps_multiple=2.0, n_nodes=4,
                                           seed=5, rate_override=0.6))
    assert fast["jct_mean"] < slow["jct_mean"]


def test_profiles_match_paper_table4():
    assert PROFILES["lam13"].avg_latency_ms == pytest.approx(8610.2)
    assert PROFILES["opt6.7"].avg_latency_ms == pytest.approx(1315.5)
    # §6.2 request-rate formula
    assert avg_request_rate(PROFILES["lam13"], 120) == pytest.approx(
        13.9, abs=0.1
    )


def test_heterogeneous_executor_uses_per_node_profiles():
    """A slow pod's window takes longer than a fast pod's for the same
    batch, and per-node token costs feed the least_eta placement."""
    from repro.core import Job
    from repro.simulate import SimExecutor

    fast, slow = PROFILES["vic"], PROFILES["lam13"]
    ex = SimExecutor(slow, node_profiles={0: fast})

    def mk():
        return Job(job_id=0, prompt="p", prompt_tokens=[1],
                   arrival_time=0.0, true_output_len=50,
                   output_tokens=[7] * 50)

    d_fast = ex.execute(0, [mk()], window=50, now=0.0).duration
    d_slow = ex.execute(1, [mk()], window=50, now=0.0).duration
    assert d_slow > d_fast
    ratio = slow.decode_ms_1 / fast.decode_ms_1
    assert d_slow / d_fast == pytest.approx(ratio, rel=0.2)

    costs = ex.node_token_cost(2)
    assert costs[0] == pytest.approx(fast.decode_ms_1 / 1000.0)
    assert costs[1] == pytest.approx(slow.decode_ms_1 / 1000.0)
    # per-node Appendix-A capacity follows each pod's own profile
    assert ex._capacity_of(0) == fast.kv_capacity_tokens()
    assert ex._capacity_of(1) == slow.kv_capacity_tokens()


def test_cluster_experiment_with_placement_and_rebalancing():
    """Full pipeline: heterogeneous cluster + least_eta + work-stealing
    completes every request (run_experiment asserts the GlobalState
    drained-to-zero invariant internally)."""
    cfg = ExperimentConfig(model="vic", n_requests=60, rps_multiple=1.2,
                           n_nodes=2, seed=4, predictor="oracle",
                           placement="least_eta", rebalance=True,
                           node_profiles={0: "vic", 1: "lam13"},
                           arrivals="bursty", burst_size=12)
    m = run_experiment(cfg)
    assert m["n_finished"] == 60 and m["n_unfinished"] == 0
    assert m["migrations"] >= 0


def test_kv_capacity_model_appendix_a():
    """Appendix A: lam13 preempts at ~batch 120 with 90% memory limit.
    capacity_tokens / (batch * avg_total_tokens_per_req) ~ 1 at onset."""
    p = PROFILES["lam13"]
    cap = p.kv_capacity_tokens()
    # avg request: ~60-token prompt + ~170-token response => ~2e2..1e3 total;
    # onset batch 120 implies per-request footprint ~ cap/120
    per_req = cap / p.preempt_batch
    assert 200 < per_req < 2000, per_req
