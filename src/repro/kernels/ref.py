"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def reference_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KH, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len=None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    rep = h // kh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_off = jnp.asarray(q_offset)
    q_pos = (jnp.arange(sq)[None, :] + q_off.reshape(-1, 1))[:, None, :, None]
    k_pos = jnp.arange(skv)[None, None, None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    if kv_len is not None:
        mask &= k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1, 1)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def reference_decode_attention(
    q: jnp.ndarray,  # (B, 1, H, D)
    k: jnp.ndarray,  # (B, L, KH, D)
    v: jnp.ndarray,
    *,
    kv_len: jnp.ndarray,  # (B,)
    q_offset: jnp.ndarray,  # (B,)
    window: Optional[int] = None,
) -> jnp.ndarray:
    return reference_attention(q, k, v, causal=True, window=window,
                               q_offset=q_offset, kv_len=kv_len)


def reference_ssd(x, a, Bm, Cm, chunk: int):
    """Chunked SSD oracle — delegates to the model-zoo reference."""
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, a, Bm, Cm, chunk)
