"""Public serving API — the online request lifecycle (paper §4.1).

The ELIS paper describes a cloud-native scheduler that admits requests
continuously.  This module is that public surface: callers construct
:class:`Request` objects, submit them to an :class:`ElisServer`, and get back
opaque :class:`RequestHandle`\\ s.  Results surface as :class:`TokenChunk`
streams (one chunk per scheduling iteration) and terminal
:class:`Response` records.  The scheduler-internal ``Job`` is an
implementation detail constructed *from* a ``Request`` — it is never handed
back to callers.

Lifecycle::

    QUEUED -> RUNNING <-> PREEMPTED -> FINISHED
                   \\-> CANCELLED (caller)  |  EXPIRED (deadline)

The server is *steppable*: ``submit`` / ``cancel`` / ``step`` / ``run_until``
may be interleaved freely, which is what the cluster simulator, the live JAX
engine, and future async dispatch all sit behind.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    Dict,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.job import Job, JobState

if TYPE_CHECKING:  # avoid a circular import (frontend imports TokenChunk)
    from repro.core.frontend import ELISFrontend, Event, FrontendConfig
    from repro.core.predictor import Predictor


class RequestStatus(enum.Enum):
    """Externally visible request state (terminal states are final)."""

    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    EXPIRED = "expired"

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.FINISHED, RequestStatus.CANCELLED,
                        RequestStatus.EXPIRED)


_STATE_TO_STATUS = {
    JobState.WAITING: RequestStatus.QUEUED,
    JobState.RUNNING: RequestStatus.RUNNING,
    JobState.PREEMPTED: RequestStatus.PREEMPTED,
    JobState.FINISHED: RequestStatus.FINISHED,
    JobState.CANCELLED: RequestStatus.CANCELLED,
    JobState.EXPIRED: RequestStatus.EXPIRED,
}


@dataclass(frozen=True)
class RequestOptions:
    """Per-request knobs, orthogonal to the prompt itself."""

    #: cap on generated tokens (None = backend's own cap)
    max_tokens: Optional[int] = None
    #: absolute deadline on the serving clock; the request is EXPIRED if it
    #: has not finished by then (slot is released at the deadline)
    deadline: Optional[float] = None
    #: multi-tenancy label, carried through to the Response
    tenant: str = "default"
    #: coarse priority band: lower classes always outrank higher ones,
    #: independent of predicted length (0 = default band)
    priority_class: int = 0
    #: caller intends to consume ``ElisServer.stream`` for this request
    stream: bool = False


@dataclass
class Request:
    """One serving request as the caller sees it."""

    prompt: str
    prompt_tokens: Sequence[int]
    arrival_time: float = 0.0
    #: caller-chosen id; None = server assigns a fresh one
    request_id: Optional[int] = None
    options: RequestOptions = field(default_factory=RequestOptions)
    #: ground-truth response length/stream — oracle predictors and the
    #: cluster simulator replay these; the live engine ignores them
    true_output_len: int = 0
    output_tokens: Sequence[int] = ()

    @classmethod
    def from_workload(cls, r, options: Optional[RequestOptions] = None
                      ) -> "Request":
        """Adapt a ``repro.data.workload.Request`` (generator ground truth).

        Without explicit ``options``, the workload record's own serving
        attributes (tenant / priority class / deadline — set by the
        scenario library, absent on plain generator output) are forwarded
        so multi-tenant scenarios flow through unchanged."""
        if options is None:
            options = RequestOptions(
                deadline=getattr(r, "deadline", None),
                tenant=getattr(r, "tenant", None) or "default",
                priority_class=int(getattr(r, "priority_class", 0) or 0),
            )
        return cls(
            prompt=r.prompt,
            prompt_tokens=r.prompt_tokens,
            arrival_time=r.arrival_time,
            request_id=r.request_id,
            options=options,
            true_output_len=r.true_output_len,
            output_tokens=r.output_tokens,
        )


@dataclass(frozen=True)
class TokenChunk:
    """Tokens emitted by one scheduling iteration of one request."""

    request_id: int
    tokens: Tuple[int, ...]
    #: scheduling-iteration index this chunk came from (0-based)
    index: int
    #: serving-clock time at which the tokens materialised
    t: float
    #: True on the request's last chunk
    final: bool = False


@dataclass
class Response:
    """Terminal record of one request (duck-compatible with ``summarize``)."""

    request_id: int
    status: RequestStatus
    tokens: Tuple[int, ...]
    node: int
    arrival_time: float
    finish_time: Optional[float]
    first_token_time: Optional[float]
    queuing_delay: float
    n_preemptions: int
    n_iterations: int
    tenant: str = "default"
    #: cross-node migrations while queued (cluster rebalancing)
    n_migrations: int = 0
    #: mean |predicted - actual| remaining tokens over the request's scored
    #: windows (None when the policy predicted no lengths or the request
    #: never finished — aborted lengths are censored)
    pred_mae: Optional[float] = None
    #: geometric mean of predicted/actual remaining (1.0 = calibrated,
    #: < 1 = the predictor underestimated this request)
    pred_bias: Optional[float] = None

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def ok(self) -> bool:
        return self.status is RequestStatus.FINISHED

    def jct(self) -> float:
        assert self.finish_time is not None
        return self.finish_time - self.arrival_time

    @classmethod
    def from_job(cls, job: Job) -> "Response":
        from repro.core.metrics import prediction_stats

        mae, bias = prediction_stats(job)
        return cls(
            request_id=job.job_id,
            status=_STATE_TO_STATUS[job.state],
            tokens=tuple(job.generated),
            node=job.node,
            arrival_time=job.arrival_time,
            finish_time=job.finish_time,
            first_token_time=job.first_token_time,
            queuing_delay=job.queuing_delay,
            n_preemptions=job.n_preemptions,
            n_iterations=job.n_iterations,
            tenant=job.tenant,
            n_migrations=job.n_migrations,
            pred_mae=mae,
            pred_bias=bias,
        )


class RequestHandle:
    """Opaque ticket for a submitted request."""

    __slots__ = ("request_id", "_server")

    def __init__(self, request_id: int, server: "ElisServer"):
        self.request_id = request_id
        self._server = server

    @property
    def status(self) -> RequestStatus:
        return self._server.status(self)

    @property
    def done(self) -> bool:
        return self.status.terminal

    def result(self) -> Optional[Response]:
        """The terminal Response, or None while the request is live."""
        return self._server.response(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"RequestHandle(id={self.request_id}, status={self.status.value})"


class ElisServer:
    """Facade over the steppable ELIS frontend — the online serving surface.

    Construct either from scheduler config + predictor + backend, or wrap an
    existing :class:`~repro.core.frontend.ELISFrontend`::

        server = ElisServer(FrontendConfig(...), OraclePredictor(), backend)
        h = server.submit(Request(prompt, tokens, arrival_time=0.0))
        for chunk in server.stream(h):
            ...
        responses = server.drain()
    """

    def __init__(self, cfg: Optional["FrontendConfig"] = None,
                 predictor: Optional["Predictor"] = None,
                 backend=None, *,
                 frontend: Optional["ELISFrontend"] = None):
        from repro.core.frontend import ELISFrontend, FrontendConfig

        if frontend is None:
            if backend is None:
                raise ValueError("ElisServer needs a backend (or a frontend)")
            frontend = ELISFrontend(cfg or FrontendConfig(), predictor,
                                    backend)
        self._fe = frontend
        self._ids = itertools.count()
        self._jobs: Dict[int, Job] = {}
        self._order: List[int] = []

    # -- introspection -------------------------------------------------- #
    @property
    def frontend(self) -> "ELISFrontend":
        return self._fe

    @property
    def backend(self):
        return self._fe.executor

    @property
    def now(self) -> float:
        """Current serving-clock time."""
        return self._fe.now

    def pending(self) -> int:
        """Number of unprocessed scheduler events."""
        return self._fe.pending()

    # -- lifecycle ------------------------------------------------------ #
    def submit(self, request: Request) -> RequestHandle:
        """Admit a request; returns an opaque handle (never the Job)."""
        rid = request.request_id
        if rid is None:
            rid = next(self._ids)
            while rid in self._jobs:
                rid = next(self._ids)
        elif rid in self._jobs:
            raise ValueError(f"duplicate request_id {rid}")
        opts = request.options
        max_out = request.true_output_len
        if opts.max_tokens is not None:
            max_out = (min(max_out, opts.max_tokens) if max_out
                       else opts.max_tokens)
        job = Job(
            job_id=rid,
            prompt=request.prompt,
            prompt_tokens=list(request.prompt_tokens),
            arrival_time=request.arrival_time,
            true_output_len=max_out,
            output_tokens=list(request.output_tokens),
            deadline=opts.deadline,
            tenant=opts.tenant,
            priority_class=opts.priority_class,
            stream=opts.stream,
        )
        self._fe.submit(job)
        self._jobs[rid] = job
        self._order.append(rid)
        return RequestHandle(rid, self)

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a live request. Waiting requests terminate immediately;
        running ones are evicted at the next window boundary.  Returns False
        if the request is unknown or already terminal."""
        return self._fe.cancel(handle.request_id)

    def status(self, handle: RequestHandle) -> RequestStatus:
        job = self._job(handle)
        return _STATE_TO_STATUS[job.state]

    def response(self, handle: RequestHandle) -> Optional[Response]:
        job = self._job(handle)
        if _STATE_TO_STATUS[job.state].terminal:
            return Response.from_job(job)
        return None

    # -- time ----------------------------------------------------------- #
    def step(self, now: Optional[float] = None) -> List["Event"]:
        """Process the next scheduler event (if due by ``now``)."""
        return self._fe.step(now)

    def run_until(self, t: float) -> List["Event"]:
        """Advance the serving clock to ``t``, processing all due events."""
        return self._fe.run_until(t)

    def drain(self) -> List[Response]:
        """Run the system to completion and return every terminal Response,
        in submission order."""
        while self._fe.pending():
            self._fe.step()
        out = []
        for rid in self._order:
            job = self._jobs[rid]
            if _STATE_TO_STATUS[job.state].terminal:
                out.append(Response.from_job(job))
        return out

    def drain_stream(self) -> Iterator[Response]:
        """Like :meth:`drain`, but yield each terminal Response and
        immediately release the underlying job's records — constant memory
        over arbitrarily long runs (pairs with the streaming aggregator in
        :mod:`repro.core.metrics`).  Responses come in submission order;
        released requests are forgotten (``status`` raises for them
        afterwards)."""
        while self._fe.pending():
            self._fe.step()
        order = list(self._order)
        try:
            for rid in order:
                job = self._jobs.get(rid)
                if job is None or not _STATE_TO_STATUS[job.state].terminal:
                    continue
                resp = Response.from_job(job)
                self._fe.forget(rid)
                del self._jobs[rid]
                yield resp
        finally:
            self._order = [rid for rid in order if rid in self._jobs]

    def release(self, handle: RequestHandle) -> bool:
        """Drop a *terminal* request's records (job, chunks, response data)
        so long-lived servers don't grow without bound.  Returns False if
        the request is unknown or still live."""
        job = self._jobs.get(handle.request_id)
        if job is None or not _STATE_TO_STATUS[job.state].terminal:
            return False
        self._fe.forget(handle.request_id)
        del self._jobs[handle.request_id]
        self._order.remove(handle.request_id)
        return True

    # -- streaming ------------------------------------------------------ #
    def stream(self, handle: RequestHandle) -> Iterator[TokenChunk]:
        """Yield the request's TokenChunks in generation order, stepping the
        scheduler as needed until the request reaches a terminal state.
        Requires the request to have been submitted with
        ``RequestOptions(stream=True)`` (chunks are only retained then)."""
        job = self._job(handle)
        if not job.stream:
            raise ValueError(
                f"request {handle.request_id} was not submitted with "
                f"options.stream=True; no chunks are retained for it")
        i = 0
        while True:
            while i < len(job.chunks):
                yield job.chunks[i]
                i += 1
            if _STATE_TO_STATUS[job.state].terminal:
                return
            if not self._fe.pending():
                return  # starved: nothing left that could produce tokens
            self._fe.step()

    # ------------------------------------------------------------------ #
    def _job(self, handle: RequestHandle) -> Job:
        try:
            return self._jobs[handle.request_id]
        except KeyError:
            raise KeyError(f"unknown request {handle.request_id}") from None
