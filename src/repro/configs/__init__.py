"""Architecture configuration registry (``--arch <id>``)."""
from repro.configs.base import (
    EncoderConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "EncoderConfig",
    "HybridConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "list_archs",
    "register",
]
