"""ELIS frontend scheduler — Algorithm 1 as an event-driven loop.

One implementation drives both backends:
  * the **cluster simulator** (``repro.simulate``) — virtual time, calibrated
    per-model latency, 50 workers on a laptop;
  * the **live JAX engine** (``repro.engine``) — real decode windows, wall
    clock measured and fed back as event durations.

Semantics (faithful to the paper):
  * iteration-level batching with a fixed window of K=50 tokens;
  * per-node PriorityBuffer; greedy min-load balancing at arrival;
  * slot *stickiness*: a running job keeps its batch slot until it finishes —
    unless the preemption policy displaces it (FCFS ⇒ non-preemptive ORCA
    behaviour; ISRTF ⇒ priority preemption at window boundaries with
    margin/frequency knobs);
  * displaced jobs pay a KV-recompute cost when they next run;
  * prompts are sent to the backend once (re-dispatch is metadata-only).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core.job import Job, JobState
from repro.core.load_balancer import GlobalState, LoadBalancer
from repro.core.predictor import Predictor
from repro.core.scheduler import (
    Policy,
    PreemptionConfig,
    SchedulerConfig,
    make_policy,
    select_preemptions,
)


class ExecResult:
    def __init__(self, duration: float, tokens: List[List[int]],
                 finished: List[bool]):
        self.duration = duration
        self.tokens = tokens
        self.finished = finished


class Executor(Protocol):
    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float) -> ExecResult: ...

    def evict(self, node: int, job: Job) -> None: ...


@dataclass
class FrontendConfig:
    n_nodes: int = 1
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    preemption: PreemptionConfig = field(default_factory=PreemptionConfig)


def batch_effective(policy: Policy, jobs: Sequence[Job], now: float) -> List[float]:
    """Assign priorities to ``jobs`` (batched through the predictor when it
    supports it) and return effective (aging-adjusted) priorities."""
    pred = policy.predictor
    if (
        policy.name == "isrtf"
        and pred is not None
        and hasattr(pred, "predict_jobs")
        and len(jobs) > 1
    ):
        raw = pred.predict_jobs(jobs)
        pris = [float(r) for r in raw]
    else:
        pris = [policy.priority(j, now) for j in jobs]
    out = []
    for j, p in zip(jobs, pris):
        j.priority = p
        j.predictions.append(p)
        eff = p
        if policy.cfg.aging_rate > 0 and j.last_enqueue_time is not None:
            eff -= policy.cfg.aging_rate * max(now - j.last_enqueue_time, 0.0)
        out.append(eff)
    return out


class ELISFrontend:
    def __init__(self, cfg: FrontendConfig, predictor: Optional[Predictor],
                 executor: Executor):
        self.cfg = cfg
        self.policy = make_policy(cfg.scheduler, predictor)
        self.executor = executor
        self.state = GlobalState(cfg.n_nodes)
        self.balancer = LoadBalancer(self.state)
        # per-node structures
        self.waiting: Dict[int, List[Job]] = {n: [] for n in range(cfg.n_nodes)}
        self.running: Dict[int, List[Job]] = {n: [] for n in range(cfg.n_nodes)}
        self.node_busy: Dict[int, bool] = {n: False for n in range(cfg.n_nodes)}
        self.finished: List[Job] = []
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()

    # ------------------------------------------------------------------ #
    def _push_event(self, t: float, kind: str, data) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, data))

    def submit(self, job: Job) -> None:
        self._push_event(job.arrival_time, "arrival", job)

    # ------------------------------------------------------------------ #
    def run(self) -> List[Job]:
        while self._events:
            now, _, kind, data = heapq.heappop(self._events)
            if kind == "arrival":
                self._on_arrival(data, now)
            elif kind == "node_free":
                self._on_node_free(data, now)
        return self.finished

    # ------------------------------------------------------------------ #
    def _on_arrival(self, job: Job, now: float) -> None:
        node = self.balancer.assign(job)
        job.state = JobState.WAITING
        job.record_enqueue(now)
        self.waiting[node].append(job)
        if not self.node_busy[node]:
            self._push_event(now, "node_free", node)
            self.node_busy[node] = True  # claimed; released when truly idle

    def _on_node_free(self, node: int, now: float) -> None:
        batch = self._form_batch(node, now)
        if not batch:
            self.node_busy[node] = False
            return
        res = self.executor.execute(node, batch,
                                    self.cfg.scheduler.window, now)
        end = now + res.duration
        for job, toks, fin in zip(batch, res.tokens, res.finished):
            job.generated.extend(toks)
            job.n_iterations += 1
            if job.first_token_time is None and toks:
                job.first_token_time = end
            if fin:
                job.finished = True
                job.state = JobState.FINISHED
                job.finish_time = end
                self.finished.append(job)
                self.running[node].remove(job)
                self.state.finish_job(node)
                self.executor.evict(node, job)
        self._push_event(end, "node_free", node)
        self.node_busy[node] = True

    # ------------------------------------------------------------------ #
    def _form_batch(self, node: int, now: float) -> List[Job]:
        cap = self.cfg.scheduler.batch_size
        running = self.running[node]
        waiting = self.waiting[node]
        if not running and not waiting:
            return []

        run_eff = batch_effective(self.policy, running, now) if running else []
        wait_eff = batch_effective(self.policy, waiting, now) if waiting else []

        # 1. preemption: displace low-priority running jobs (margin-gated)
        swaps = select_preemptions(
            list(zip(run_eff, running)), list(zip(wait_eff, waiting)),
            self.cfg.preemption,
        )
        for victim, repl in swaps:
            running.remove(victim)
            victim.state = JobState.PREEMPTED
            victim.n_preemptions += 1
            victim.record_enqueue(now)
            waiting.append(victim)
            self.executor.evict(node, victim)
            waiting.remove(repl)
            repl.state = JobState.RUNNING
            repl.record_dispatch(now)
            running.append(repl)

        # 2. fill free slots with the best remaining waiters
        free = cap - len(running)
        if free > 0 and waiting:
            order = sorted(
                zip(batch_effective(self.policy, waiting, now), itertools.count(), waiting)
            )
            for _, _, job in order[:free]:
                waiting.remove(job)
                job.state = JobState.RUNNING
                job.record_dispatch(now)
                running.append(job)
        return list(running)
