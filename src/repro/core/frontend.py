"""ELIS frontend scheduler — Algorithm 1 as a *steppable* event loop.

One implementation drives both backends:
  * the **cluster simulator** (``repro.simulate``) — virtual time, calibrated
    per-model latency, 50 workers on a laptop;
  * the **live JAX engine** (``repro.engine``) — real decode windows, wall
    clock measured and fed back as event durations.

Semantics (faithful to the paper):
  * iteration-level batching with a fixed window of K=50 tokens;
  * ONE fused scoring pass per window: ``running + waiting`` are scored in
    a single :func:`repro.core.scheduler.score_pool` call (one batched,
    shape-bucketed predictor dispatch), split back into per-queue
    priorities; ``SchedulerConfig.repredict_every`` stretches the encoder
    cadence — between full re-scores a job reuses its cached prediction
    decayed by the tokens generated since it was scored;
  * per-node PriorityBuffer; pluggable placement at arrival
    (``FrontendConfig.placement``): greedy min-job-count (``least_jobs``,
    the paper's line 3), outstanding-predicted-tokens balancing
    (``least_predicted_work``), or per-node drain-time estimation over the
    calibrated latency profile (``least_eta``, which reads the now-live
    ``GlobalState.busy_until`` horizon);
  * optional cross-node rebalancing (``FrontendConfig.rebalance``): at each
    ``node_free`` event an under-loaded node steals the best queued jobs
    from the most-loaded node's waiting queue when the predicted-work
    imbalance exceeds a threshold — queued-only migration, so nothing with
    live KV state moves (a migrated PREEMPTED job abandons its old node's
    KV and pays the usual recompute on dispatch);
  * slot *stickiness*: a running job keeps its batch slot until it finishes —
    unless the preemption policy displaces it (FCFS ⇒ non-preemptive ORCA
    behaviour; ISRTF ⇒ priority preemption at window boundaries with
    margin/frequency knobs);
  * displaced jobs pay a KV-recompute cost when they next run;
  * prompts are sent to the backend once (re-dispatch is metadata-only).

Online extensions (paper §4.1, "continuously admits requests"):
  * the event heap is **resumable** — ``step``/``run_until`` interleave with
    late ``submit``/``cancel`` calls instead of the drain-once ``run``;
  * cancellation and deadline expiry flow through the scheduler: the job is
    evicted from its backend (releasing the slot) and surfaces as a terminal
    ``CANCELLED``/``EXPIRED`` state; expiry is enforced at the window
    boundary — tokens a window would deliver past the deadline are dropped,
    so no job ever finishes with ``finish_time > deadline``;
  * every window emits per-job :class:`~repro.core.api.TokenChunk`\\ s, the
    unit of streaming.
"""
from __future__ import annotations

import abc
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.api import TokenChunk
from repro.core.job import TERMINAL_STATES, Job, JobState
from repro.core.load_balancer import GlobalState, LoadBalancer, make_placement
from repro.core.predictor import Predictor
from repro.core.scheduler import (
    PRIORITY_CLASS_WEIGHT,
    Policy,
    PreemptionConfig,
    SchedulerConfig,
    batch_effective,
    cached_expected_remaining,
    cached_raw_priority,
    decide_preempt,
    effective_priority,
    make_policy,
    prefill_debt,
    score_pool,
    select_fills,
    select_preemptions,
)

__all__ = [
    "Backend", "ELISFrontend", "Event", "ExecResult",
    "FrontendConfig",
    # re-exported for callers that historically imported these from here —
    # the implementations now live in repro.core.scheduler
    "PRIORITY_CLASS_WEIGHT", "batch_effective",
]


class ExecResult:
    def __init__(self, duration: float, tokens: List[List[int]],
                 finished: List[bool]):
        self.duration = duration
        self.tokens = tokens
        self.finished = finished


class Backend(abc.ABC):
    """Execution backend behind the frontend (simulator or live engine).

    ``execute`` runs one scheduling window for a batch and reports the new
    tokens (which the frontend re-emits as per-window ``TokenChunk``\\ s);
    ``evict`` releases a job's backend residency (finish / preemption /
    cancellation / expiry all route through it); ``free_capacity`` bounds
    batch admissions when the backend is tighter than the configured batch
    size (``capacity`` is the static counterpart, for introspection).
    """

    @abc.abstractmethod
    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float) -> ExecResult: ...

    @abc.abstractmethod
    def evict(self, node: int, job: Job) -> None: ...

    def offload(self, node: int, job: Job) -> bool:
        """Preempt ``job`` but *keep* its KV by swapping it to host memory
        (ALISE tier).  Returns False when the backend cannot swap (no
        cache, unsupported family) — the caller then falls back to
        :meth:`evict` + recompute-on-resume.  Backends that support it
        must restore the cache transparently when the job is next
        executed."""
        return False

    def restore(self, node: int, job: Job) -> bool:
        """Explicitly swap a previously offloaded job's KV back in.
        Optional — ``execute`` must restore lazily regardless."""
        return False

    def preempt_costs(self, node: int, job: Job
                      ) -> Optional[Tuple[float, float]]:
        """(swap_round_trip_s, recompute_s) estimates for preempting
        ``job`` — the ``auto`` preempt policy's break-even input.  None =
        the backend cannot price the trade (caller recomputes)."""
        return None

    def capacity(self, node: int) -> Optional[int]:
        """Max concurrent jobs node can hold; None = unbounded."""
        return None

    def free_capacity(self, node: int) -> Optional[int]:
        """Currently free job slots on ``node``; None = unbounded."""
        return None

    def counters(self) -> Dict[str, int]:
        """Backend-specific compile/dispatch counters for introspection
        (e.g. the live engine's recompile-storm hooks); {} = none."""
        return {}


@dataclass(frozen=True)
class Event:
    """One observable lifecycle transition, emitted by ``step``."""

    t: float
    #: arrival | tokens | preempted | migrated | finished | cancelled | expired
    kind: str
    job_id: int
    chunk: Optional[TokenChunk] = None


@dataclass
class FrontendConfig:
    n_nodes: int = 1
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    preemption: PreemptionConfig = field(default_factory=PreemptionConfig)
    #: placement policy at arrival: least_jobs | least_predicted_work |
    #: least_eta (see repro.core.load_balancer)
    placement: str = "least_jobs"
    #: seconds per generated token per node, for ``least_eta`` on
    #: heterogeneous clusters (None = uniform nodes)
    node_token_cost: Optional[Dict[int, float]] = None
    #: enable cross-node work-stealing of queued jobs at node_free events
    rebalance: bool = False
    #: predicted-work imbalance (tokens) that triggers stealing
    rebalance_threshold: float = 200.0
    #: cap on jobs stolen per node_free event
    max_migrations_per_free: int = 4
    #: feed the predictor ground-truth remaining length on EVERY window
    #: (``predictor.observe``) — exact in trace replay / simulation, where
    #: ``true_output_len`` is the realised length.  A live engine only
    #: learns a request's length at its finish, so the serving launcher
    #: turns this off and calibration runs on finish observations alone.
    observe_in_flight: bool = True


class ELISFrontend:
    def __init__(self, cfg: FrontendConfig, predictor: Optional[Predictor],
                 executor: Backend):
        self.cfg = cfg
        self.policy = make_policy(cfg.scheduler, predictor)
        self.executor = executor
        #: online-feedback hook (no-op on raw predictors, residual/bias
        #: updates on calibration wrappers); None for predictor-less
        #: policies and legacy predictor objects
        self._observe = getattr(predictor, "observe", None)
        self.state = GlobalState(cfg.n_nodes)
        self.balancer = LoadBalancer(
            self.state, make_placement(cfg.placement, cfg.node_token_cost))
        #: rebalancing is meaningful only across nodes
        self._rebalance_active = cfg.rebalance and cfg.n_nodes > 1
        #: predicted-work accounting has a consumer
        self._track_work = (self.balancer.placement.uses_work
                            or self._rebalance_active)
        if self._track_work and predictor is None:
            # without length predictions, work-aware placement degrades to
            # the count tie-break and the rebalancer never finds work to
            # steal — fail loudly instead of silently measuring least_jobs
            raise ValueError(
                f"placement={cfg.placement!r}"
                f"{' with rebalance' if self._rebalance_active else ''} "
                f"requires a predictor (got None)")
        #: cross-node migrations performed by the rebalancing pass
        self.migrations = 0
        # per-node structures
        self.waiting: Dict[int, List[Job]] = {n: [] for n in range(cfg.n_nodes)}
        self.running: Dict[int, List[Job]] = {n: [] for n in range(cfg.n_nodes)}
        self.node_busy: Dict[int, bool] = {n: False for n in range(cfg.n_nodes)}
        #: scheduling windows formed per node — drives the re-prediction
        #: stride (``SchedulerConfig.repredict_every``)
        self._windows: Dict[int, int] = {n: 0 for n in range(cfg.n_nodes)}
        self.finished: List[Job] = []
        #: cancelled + expired jobs (terminal but not FINISHED)
        self.terminated: List[Job] = []
        self.jobs: Dict[int, Job] = {}
        self.now: float = 0.0
        self._events: List[Tuple[float, int, int, str, object]] = []
        self._seq = itertools.count()
        #: lifecycle events produced outside step() (e.g. immediate cancels),
        #: flushed into the next step()/run_until() return value
        self._side_events: List[Event] = []

    #: tie-break at equal timestamps: arrivals land before deadline checks,
    #: which land before node scheduling — so a job arriving exactly when a
    #: node frees is schedulable in that very window, regardless of whether
    #: it was submitted before or after the simulation started (this keeps
    #: interleaved step()/submit() traces identical to drain-once runs)
    _KIND_RANK = {"arrival": 0, "deadline": 1, "node_free": 2}

    # ------------------------------------------------------------------ #
    def _push_event(self, t: float, kind: str, data) -> None:
        heapq.heappush(self._events,
                       (t, self._KIND_RANK[kind], next(self._seq), kind, data))

    def submit(self, job: Job) -> None:
        """Admit a job.  May be called at any point — before, between, or
        after ``step``/``run_until`` calls.  Arrivals dated before the
        current clock are admitted at the current clock."""
        self.jobs[job.job_id] = job
        t = max(job.arrival_time, self.now)
        self._push_event(t, "arrival", job)
        if job.deadline is not None:
            self._push_event(max(job.deadline, t), "deadline", job)

    def cancel(self, job_id: int) -> bool:
        """Cancel a live job.  Waiting (or not-yet-arrived) jobs terminate
        immediately; running jobs are evicted at the next window boundary.
        Returns False for unknown or already-terminal jobs."""
        job = self.jobs.get(job_id)
        if job is None or job.state in TERMINAL_STATES:
            return False
        node = job.node
        if node >= 0 and job in self.waiting.get(node, ()):
            self.waiting[node].remove(job)
            self._terminate(job, node, JobState.CANCELLED, self.now,
                            self._side_events)
        else:
            # running (evicted when its node next schedules) or not yet
            # arrived (terminated at its arrival event)
            job.cancel_requested = True
        return True

    def forget(self, job_id: int) -> bool:
        """Drop a *terminal* job's record (long-lived servers release
        completed requests to bound memory).  Returns False if the job is
        unknown or still live."""
        job = self.jobs.get(job_id)
        if job is None or job.state not in TERMINAL_STATES:
            return False
        del self.jobs[job_id]
        if job in self.finished:
            self.finished.remove(job)
        elif job in self.terminated:
            self.terminated.remove(job)
        return True

    # ------------------------------------------------------------------ #
    def pending(self) -> int:
        """Unprocessed scheduler events."""
        return len(self._events)

    def next_event_time(self) -> Optional[float]:
        return self._events[0][0] if self._events else None

    def step(self, now: Optional[float] = None) -> List[Event]:
        """Process the single next event.  With ``now`` given, only events
        due by ``now`` are processed (and the clock advances to at most
        ``now``).  Returns the lifecycle events the step produced."""
        out: List[Event] = []
        if self._side_events:
            out.extend(self._side_events)
            self._side_events.clear()
        if not self._events:
            return out
        if now is not None and self._events[0][0] > now:
            self.now = max(self.now, now)
            return out
        t, _, _, kind, data = heapq.heappop(self._events)
        self.now = max(self.now, t)
        if kind == "arrival":
            self._on_arrival(data, t, out)
        elif kind == "node_free":
            self._on_node_free(data, t, out)
        elif kind == "deadline":
            self._on_deadline(data, t, out)
        return out

    def run_until(self, t: float) -> List[Event]:
        """Process every event due by ``t`` and advance the clock to ``t``."""
        out: List[Event] = []
        while self._events and self._events[0][0] <= t:
            out.extend(self.step())
        out.extend(self._side_events)
        self._side_events.clear()
        self.now = max(self.now, t)
        return out

    def run(self) -> List[Job]:
        """Drain every pending event (legacy closed-loop mode) and return
        the finished jobs."""
        while self._events:
            self.step()
        return self.finished

    # ------------------------------------------------------------------ #
    def _terminate(self, job: Job, node: int, state: JobState, t: float,
                   out: List[Event]) -> None:
        """Move a non-finished job to a terminal state, releasing its
        backend residency and its load-balancer count."""
        assert job.state not in TERMINAL_STATES
        job.state = state
        job.finish_time = t
        job.cancel_requested = False
        self.executor.evict(node, job)
        # retract the live count AND the predicted-work contribution — a job
        # cancelled/expired while still queued (never dispatched) must not
        # leave phantom work behind (GlobalState totals return to zero once
        # everything is terminal)
        self.state.finish_job(node, job.job_id)
        self.terminated.append(job)
        if self._observe is not None:
            # notify the calibrator so it drops the job's pending residuals
            # (CANCELLED/EXPIRED lengths are censored — never learned from)
            self._observe(job, 0.0)
        out.append(Event(t, state.value, job.job_id))

    def _on_arrival(self, job: Job, now: float, out: List[Event]) -> None:
        if job.cancel_requested:
            # cancelled (or expired) before it ever reached a node
            expired = job.deadline is not None and now >= job.deadline
            job.state = (JobState.EXPIRED if expired else JobState.CANCELLED)
            job.finish_time = now
            job.cancel_requested = False
            self.terminated.append(job)
            out.append(Event(now, job.state.value, job.job_id))
            return
        node = self.balancer.assign(job, self._arrival_estimate(job), now)
        job.state = JobState.WAITING
        job.record_enqueue(now)
        self.waiting[node].append(job)
        out.append(Event(now, "arrival", job.job_id))
        if not self.node_busy[node]:
            self._push_event(now, "node_free", node)
            self.node_busy[node] = True  # claimed; released when truly idle

    def _on_deadline(self, job: Job, now: float, out: List[Event]) -> None:
        if job.state in TERMINAL_STATES:
            return
        node = job.node
        if node >= 0 and job in self.waiting.get(node, ()):
            self.waiting[node].remove(job)
            self._terminate(job, node, JobState.EXPIRED, now, out)
        elif node >= 0 and job in self.running.get(node, ()):
            self.running[node].remove(job)
            self._terminate(job, node, JobState.EXPIRED, now, out)
        else:
            # not yet arrived: expire at its arrival event
            job.cancel_requested = True

    def _arrival_estimate(self, job: Job) -> float:
        """Predicted response length at arrival, for placement/rebalancing.

        Only spent when something consumes predicted work (a work-aware
        placement policy or the rebalancer) AND a predictor is available —
        ``least_jobs`` without rebalancing therefore never touches the
        predictor at arrival, which keeps its traces bit-identical to the
        pre-cluster-layer balancer (stochastic predictors draw RNG per
        call, in call order).  The ordering policy need not consume
        predictions itself: prediction-aware *placement* over FCFS nodes
        (Qiu et al.'s proxy-model setting) is exactly ``policy=fcfs`` plus
        a predictor here.
        """
        if not self._track_work:
            return 0.0
        pred = self.policy.predictor
        if pred is None:
            return 0.0
        from repro.core.predictor import predict_lengths

        # the *expectation* (debiased when a calibration wrapper is
        # composed in) — work-aware placement balances expected tokens
        return max(predict_lengths(pred, [job])[0].mean, 0.0)

    def _rebalance(self, node: int, now: float, out: List[Event]) -> None:
        """Work-stealing at a ``node_free`` event: while the most-loaded
        node's predicted-work backlog exceeds ours by more than the
        threshold, steal its best queued job (the one its ISRTF order would
        run next).  Queued-only migration — RUNNING jobs never move, so no
        live KV state crosses nodes; a stolen PREEMPTED job abandons its
        old node's cache and pays the normal recompute at dispatch."""
        cfg = self.cfg
        work = self.state.predicted_work
        for _ in range(cfg.max_migrations_per_free):
            # consider sources most-loaded first: the max node may hold all
            # its work in RUNNING jobs (nothing stealable), while a lesser
            # but still over-threshold node has a queue to relieve
            best = None
            for src in sorted(work, key=lambda n: (-work[n], n)):
                gap = work[src] - work[node]
                if src == node or gap <= cfg.rebalance_threshold:
                    break  # descending order: no further source qualifies
                for job in self.waiting[src]:
                    w = self.state.work_of(job.job_id)
                    # moving must strictly shrink the gap (0 < w < gap)
                    if 0.0 < w < gap and (best is None or w < best[0]):
                        best = (w, job)
                if best is not None:
                    break
            if best is None:
                return
            _, job = best
            src = job.node
            self.waiting[src].remove(job)
            if job.state is JobState.PREEMPTED:
                # its KV residue on the old node is dead weight — release it
                self.executor.evict(src, job)
            job.node = node
            self.state.move_job(job.job_id, node)
            self.waiting[node].append(job)
            job.n_migrations += 1
            self.migrations += 1
            out.append(Event(now, "migrated", job.job_id))

    def _wake_idle_nodes(self, node: int, now: float) -> None:
        """Give idle peers a chance to steal from our leftover queue (their
        own ``node_free`` streams stop once they drain).  Only peers whose
        predicted-work gap to us clears the steal threshold are woken —
        anything closer would scan the queues and do nothing."""
        work = self.state.predicted_work
        for m in self.node_busy:
            if not self.node_busy[m] \
                    and work[node] - work[m] > self.cfg.rebalance_threshold:
                self._push_event(now, "node_free", m)
                self.node_busy[m] = True

    def _sweep_cancelled(self, node: int, now: float,
                         out: List[Event]) -> None:
        """Honour cancel requests against running jobs (window boundary)."""
        for job in list(self.running[node]):
            if job.cancel_requested:
                self.running[node].remove(job)
                self._terminate(job, node, JobState.CANCELLED, now, out)

    def _on_node_free(self, node: int, now: float, out: List[Event]) -> None:
        self._sweep_cancelled(node, now, out)
        if self._rebalance_active:
            self._rebalance(node, now, out)
        batch = self._form_batch(node, now, out)
        if not batch:
            self.node_busy[node] = False
            return
        pc = self.cfg.scheduler.prefill_chunk
        if pc is not None:
            # kwarg only when configured: Backend.execute's positional
            # signature is unchanged for chunk-unaware backends
            res = self.executor.execute(node, batch,
                                        self.cfg.scheduler.window, now,
                                        prefill_chunk=pc)
        else:
            res = self.executor.execute(node, batch,
                                        self.cfg.scheduler.window, now)
        end = now + res.duration
        # the horizon this window runs to — least_eta placement reads it
        self.state.note_busy(node, end)
        for job, toks, fin in zip(batch, res.tokens, res.finished):
            if job.deadline is not None and end > job.deadline:
                # the window straddles the deadline: its tokens materialise
                # at the window boundary ``end``, i.e. past the deadline —
                # drop them and expire the job at the deadline instead of
                # letting it FINISH with finish_time > deadline (the pending
                # deadline event would fire too late to stop that)
                self.running[node].remove(job)
                self._terminate(job, node, JobState.EXPIRED, job.deadline,
                                out)
                continue
            job.generated.extend(toks)
            # progress-based decay of the job's predicted-work contribution
            # (kept fresh between scoring refreshes; the next scoring pass
            # overwrites it with the policy's own remaining-length estimate
            # when the policy predicts lengths)
            if toks and self.state.work_of(job.job_id) > 0:
                self.state.set_work(
                    job.job_id,
                    max(self.state.work_of(job.job_id) - len(toks), 0.0))
            iteration = job.n_iterations
            job.n_iterations += 1
            if job.first_token_time is None and toks:
                job.first_token_time = end
            if toks or fin:
                chunk = TokenChunk(request_id=job.job_id,
                                   tokens=tuple(toks), index=iteration,
                                   t=end, final=fin)
                if job.stream:
                    job.chunks.append(chunk)
                out.append(Event(end, "tokens", job.job_id, chunk))
            if fin:
                job.finished = True
                job.state = JobState.FINISHED
                job.finish_time = end
                self.finished.append(job)
                self.running[node].remove(job)
                self.state.finish_job(node, job.job_id)
                self.executor.evict(node, job)
                if self._observe is not None:
                    # finish reveals the exact length: resolve every logged
                    # prediction into a residual (actual_remaining == 0)
                    self._observe(job, 0.0)
                out.append(Event(end, "finished", job.job_id))
            elif (self._observe is not None and self.cfg.observe_in_flight
                  and job.true_output_len > 0):
                # mid-flight ground truth (trace replay / simulation only —
                # see FrontendConfig.observe_in_flight): calibrators adapt
                # within a window or two instead of waiting for finishes
                self._observe(job, float(job.true_remaining))
        self._push_event(end, "node_free", node)
        self.node_busy[node] = True
        if self._rebalance_active and self.waiting[node]:
            self._wake_idle_nodes(node, now)

    # ------------------------------------------------------------------ #
    def _form_batch(self, node: int, now: float,
                    out: List[Event]) -> List[Job]:
        cap = self.cfg.scheduler.batch_size
        running = self.running[node]
        waiting = self.waiting[node]
        if not running and not waiting:
            return []

        # ONE fused predictor pass over running + waiting per window (two
        # separate dispatches would double the per-window predictor latency
        # sitting on the scheduling critical path); every repredict_every-th
        # window is a full re-score, in between cached predictions are
        # decayed by progress (new arrivals are still scored fresh)
        widx = self._windows[node]
        self._windows[node] = widx + 1
        stride = max(self.cfg.scheduler.repredict_every, 1)
        run_eff, wait_eff = score_pool(self.policy, running, waiting, now,
                                       full=(widx % stride == 0))
        # step 2 reuses these (no second scoring pass)
        eff = {j.job_id: e for j, e in zip(waiting, wait_eff)}

        # refresh the cluster layer's predicted-work view from the raw
        # (un-banded, un-aged) remaining-length scores this window used —
        # skipped entirely when nothing consumes predicted work (default
        # least_jobs placement without rebalancing keeps PR 2's hot path)
        # (the *expectation*, not the risk quantile — summing upper
        # quantiles across a node would systematically over-count its load)
        if self._track_work and self.policy.predicts_length:
            for j in running:
                self.state.set_work(
                    j.job_id, max(cached_expected_remaining(j), 0.0))
            for j in waiting:
                self.state.set_work(
                    j.job_id, max(cached_expected_remaining(j), 0.0))

        # backend capacity snapshot BEFORE preemption: a swap is net-zero on
        # residency (victim evicted now, replacement occupies the slot at
        # dispatch), so reading free_capacity after the evictions would
        # double-count the freed slots and overfill the backend
        fc = getattr(self.executor, "free_capacity", None)
        backend_free = fc(node) if fc is not None else None

        # 1. preemption: displace low-priority running jobs (margin-gated)
        swaps = select_preemptions(
            list(zip(run_eff, running)), list(zip(wait_eff, waiting)),
            self.cfg.preemption,
        )
        pcfg = self.cfg.preemption
        for victim, repl in swaps:
            running.remove(victim)
            victim.state = JobState.PREEMPTED
            victim.n_preemptions += 1
            victim.record_enqueue(now)
            waiting.append(victim)
            # swap-vs-recompute (PreemptionConfig.policy): costs are priced
            # BEFORE the offload/evict mutates the victim's cache state
            mode = "recompute"
            if pcfg.policy != "recompute":
                mode = decide_preempt(
                    pcfg, self.executor.preempt_costs(node, victim),
                    cached_expected_remaining(victim))
            if mode == "swap" and not self.executor.offload(node, victim):
                mode = "recompute"  # backend can't swap this job
            if mode == "recompute":
                self.executor.evict(node, victim)
            out.append(Event(now, "preempted", victim.job_id))
            # freshly re-enqueued at ``now`` ⇒ zero aging: re-band the same
            # (possibly stale-decayed) raw priority this window's scoring
            # pass used — NOT the undecayed cached prediction, which would
            # rank the victim inconsistently against stale-scored waiters.
            # The prefill debt is re-read AFTER the evict/offload above: a
            # recompute-evicted victim's debt is its whole context, a
            # swapped one's is unchanged
            eff[victim.job_id] = effective_priority(
                self.cfg.scheduler, victim,
                cached_raw_priority(victim)
                + prefill_debt(self.cfg.scheduler, victim), now)
            eff.pop(repl.job_id, None)
            waiting.remove(repl)
            repl.state = JobState.RUNNING
            repl.record_dispatch(now)
            running.append(repl)

        # 2. fill free slots with the best remaining waiters, reusing the
        #    step-1 priorities (membership changes were patched in above);
        #    the backend's own capacity bounds admissions when it is tighter
        #    than the configured batch size
        free = cap - len(running)
        if backend_free is not None:
            free = min(free, backend_free)
        if free > 0 and waiting:
            picks = select_fills([eff[job.job_id] for job in waiting], free)
            for job in [waiting[k] for k in picks]:
                waiting.remove(job)
                job.state = JobState.RUNNING
                job.record_dispatch(now)
                running.append(job)
        return list(running)
