"""Paper Fig. 6: ISRTF-vs-FCFS JCT improvement across batch sizes × RPS.

The paper observes positive improvement almost everywhere (up to 19.58% at
batch 1 / RPS 1x) and that very high load with small batches erodes the
advantage (the queue saturates and throughput dominates)."""
from __future__ import annotations

from repro.core.metrics import improvement
from repro.simulate import ExperimentConfig, compare_policies

from benchmarks.common import save_results


def run(quick: bool = False):
    batches = [1, 4] if quick else [1, 2, 4]
    rps_list = [1.0, 3.0] if quick else [1.0, 3.0, 5.0]
    n_req = 100 if quick else 200
    rows = []
    for b in batches:
        for rps in rps_list:
            cfg = ExperimentConfig(model="lam13", n_requests=n_req,
                                   batch_size=b, rps_multiple=rps, seed=11)
            res = compare_policies(cfg, ("fcfs", "isrtf"),
                                   n_trials=2 if quick else 3)
            rows.append({
                "batch_size": b,
                "rps_multiple": rps,
                "improvement_pct": round(improvement(res["fcfs"],
                                                     res["isrtf"]), 2),
                "fcfs_jct": round(res["fcfs"]["jct_mean"], 2),
                "isrtf_jct": round(res["isrtf"]["jct_mean"], 2),
            })
    save_results("fig6_batch_sizes", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
