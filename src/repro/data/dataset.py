"""Dataset builders for the response-length predictor.

Mirrors the paper's §4.2 construction: each (prompt, answer) pair yields
*step samples* — one per 50-token iteration window — whose input is
``[CLS] prompt [SEP] answer[:k*50]`` and whose label is the *remaining*
length ``len(answer) - k*50``.  Outlier removal (IQR on log-length) and the
6:2:2 split follow the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import CLS_ID, PAD_ID, SEP_ID, HashTokenizer
from repro.data.workload import Request, WorkloadGenerator

WINDOW = 50  # tokens per scheduling iteration (paper §4.1)


@dataclass
class StepSample:
    tokens: List[int]
    remaining: int
    step: int  # iteration index (0 = prompt only)
    request_id: int


def build_step_samples(requests: Sequence[Request], *, max_steps: int = 8,
                       max_len: int = 512) -> List[StepSample]:
    out: List[StepSample] = []
    for r in requests:
        total = r.true_output_len
        n_steps = min(max_steps, total // WINDOW + 1)
        for k in range(n_steps):
            consumed = k * WINDOW
            remaining = total - consumed
            if remaining <= 0:
                break
            toks = clip_step_input(r.prompt_tokens,
                                   r.output_tokens[:consumed], max_len)
            out.append(
                StepSample(tokens=toks, remaining=remaining,
                           step=k, request_id=r.request_id)
            )
    return out


def clip_step_input(prompt_tokens, generated, max_len: int) -> List[int]:
    """[CLS] prompt [SEP] <most-recent output tokens that fit>.

    Keeps the *tail* of the partial output — the recent tokens carry the
    completion signal (closing phase) that iterative prediction exploits."""
    head = [CLS_ID] + list(prompt_tokens) + [SEP_ID]
    room = max(max_len - len(head), 0)
    return (head + list(generated)[-room:])[:max_len]


def iqr_filter(samples: List[StepSample]) -> List[StepSample]:
    """Paper: remove outliers via IQR on log-transformed lengths."""
    logs = np.log([s.remaining for s in samples])
    q1, q3 = np.percentile(logs, [25, 75])
    iqr = q3 - q1
    lo, hi = q1 - 1.5 * iqr, q3 + 1.5 * iqr
    return [s for s, l in zip(samples, logs) if lo <= l <= hi]


def split_622(samples: List[StepSample], seed: int = 0):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(samples))
    n = len(samples)
    a, b = int(0.6 * n), int(0.8 * n)
    pick = lambda ids: [samples[i] for i in ids]
    return pick(idx[:a]), pick(idx[a:b]), pick(idx[b:])


#: smallest sequence bucket — shorter inputs all share one compiled shape
MIN_SEQ_BUCKET = 32


def batch_bucket(n: int) -> int:
    """Smallest power of two >= ``n`` (>= 1) — the padded batch size.

    Bucketing the batch dimension means a jitted apply compiles once per
    bucket instead of once per distinct pool size (an XLA retrace storm
    when the pool grows one job at a time)."""
    return 1 << max(n - 1, 0).bit_length()


def seq_bucket(n: int, max_len: int, min_bucket: int = MIN_SEQ_BUCKET) -> int:
    """Padded sequence length: the power-of-two ladder
    ``min_bucket, 2*min_bucket, ... , max_len`` (capped at ``max_len``)."""
    return min(batch_bucket(max(n, min_bucket)), max_len)


def n_shape_buckets(max_batch: int, max_len: int,
                    min_bucket: int = MIN_SEQ_BUCKET) -> int:
    """Upper bound on distinct (batch, seq) shapes the bucketing can emit
    for pools up to ``max_batch`` — the recompile-storm guard bound."""
    batches = {batch_bucket(b) for b in range(1, max(max_batch, 1) + 1)}
    seqs = {seq_bucket(s, max_len, min_bucket)
            for s in range(1, max(max_len, 1) + 1)}
    return len(batches) * len(seqs)


def pad_batch(samples: Sequence[StepSample], max_len: int) -> Dict[str, np.ndarray]:
    b = len(samples)
    tokens = np.full((b, max_len), PAD_ID, np.int32)
    mask = np.zeros((b, max_len), bool)
    labels = np.zeros((b,), np.float32)
    steps = np.zeros((b,), np.int32)
    for i, s in enumerate(samples):
        t = s.tokens[:max_len]
        tokens[i, : len(t)] = t
        mask[i, : len(t)] = True
        labels[i] = s.remaining
        steps[i] = s.step
    return {"tokens": tokens, "mask": mask, "labels": labels, "steps": steps}


def batch_iterator(samples: List[StepSample], batch_size: int, max_len: int,
                   seed: int = 0, loop: bool = True) -> Iterator[Dict]:
    rng = np.random.RandomState(seed)
    while True:
        order = rng.permutation(len(samples))
        for i in range(0, len(samples) - batch_size + 1, batch_size):
            chunk = [samples[j] for j in order[i : i + batch_size]]
            yield pad_batch(chunk, max_len)
        if not loop:
            return


def make_predictor_dataset(n_requests: int = 2000, *, seed: int = 0,
                           max_len: int = 256, max_steps: int = 8):
    """End-to-end: workload -> step samples -> IQR filter -> 6:2:2 split."""
    gen = WorkloadGenerator(seed=seed)
    reqs = gen.sample_requests(n_requests)
    samples = iqr_filter(build_step_samples(reqs, max_steps=max_steps,
                                            max_len=max_len))
    return split_622(samples, seed=seed)
