"""Multi-device serving: tensor-parallel pods behind real placement, with
the live↔sim loop closed at cluster scale.

The cluster is heterogeneous **by construction**, not by fiat: pod 0 is a
TP=2 engine (two devices, XLA-inserted collectives on every matmul
reduction) and pod 1 a TP=1 single-device engine — on forced CPU host
devices the TP=2 pod pays real collective/dispatch overhead, so the two
pods have genuinely different measured token costs.  The benchmark then:

1. **calibrates each pod live** — probe windows per (batch, window) shape,
   per-node least-squares fits via ``EngineExecutor.calibrated_node_profiles``
   (the first window of every shape pays XLA compile and is dropped);
2. **serves the same workload** through the online :class:`ElisServer`
   under ``least_jobs`` vs ``least_eta`` placement, where ``least_eta``
   consumes the *fitted* per-pod token costs (tentpole: placement policies
   against wall-clock backends, not latency models);
3. **replays the fitted cluster in sim** — a :class:`SimExecutor` with the
   per-node fitted profiles and fitted window overhead re-runs the
   identical workload; mean JCT must land within 1.5× of live;
4. **scales the replay 100×** through ``repro.simulate.scale`` with the
   fitted :class:`ModelProfile` objects plugged in directly (no registry
   round-trip) — the production-scale projection of *this* live cluster.

A standalone **pallas-under-mesh cell** (smoke and full) additionally runs
TP=2 decode with the ``shard_map``'d Pallas kernel vs the XLA path on the
same mesh: it asserts the kernel actually ran (``pallas_fallback is
False``) and that greedy tokens are bit-identical, and records tokens/s
for both.  On forced CPU host devices the kernel executes
``interpret=True``, so the cell is a correctness + plumbing record — the
perf claim is a TPU claim (see ``docs/kernels.md``).

Needs ≥3 host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.multi_device [--smoke|--full]

Emits ``BENCH_multi_device.json`` at the repo root (committed).
``--smoke`` is the CI multi-device guard: per-pod trace bounds, counter
separability, and a loosened live↔sim band (CI timing noise).
"""
from __future__ import annotations

import argparse
import json
import os
import time

if __name__ == "__main__":
    # direct CLI runs force the 8-device host before jax initialises; when
    # imported (benchmarks.run harness / CI step) the caller sets XLA_FLAGS
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElisServer,
    FrontendConfig,
    OraclePredictor,
    PreemptionConfig,
    Request,
    SchedulerConfig,
    summarize,
)
from repro.core.job import Job
from repro.data.workload import ScaleWorkload, scale_workload_requests
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.simulate import SimExecutor
from repro.simulate.scale import ScaleSimConfig, ScaleSimulator

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_multi_device.json")

SLOTS = 2
WINDOW = 8
#: probe grid: every decode shape serving will dispatch, so probing doubles
#: as warmup and the placement comparison never pays compile mid-run
PROBE_WINDOWS = (4, 8, 16)


def _pods(cfg, params, ecfg):
    """Pod 0: TP=2 over devices[0:2]; pod 1: TP=1 on devices[2] — disjoint
    meshes, one host param copy device_put onto each."""
    devs = jax.devices()
    return {
        0: InferenceEngine(cfg, params, ecfg,
                           mesh=make_mesh((2,), ("model",),
                                          devices=devs[:2])),
        1: InferenceEngine(cfg, params, ecfg,
                           mesh=make_mesh((1,), ("model",),
                                          devices=devs[2:3])),
    }


def _pallas_cell(cfg, params, smoke: bool) -> dict:
    """TP=2 decode, shard_map'd Pallas kernel vs XLA on the same mesh.

    Asserts ``pallas_fallback is False`` (the kernel really ran — the CI
    smoke's pallas-under-mesh guard) and greedy-token identity between the
    two impls, then times decode-only windows for a tokens/s record.
    """
    mesh = make_mesh((2,), ("model",), devices=jax.devices()[:2])
    n_timed = 2 if smoke else 6
    tokens, tok_s = {}, {}
    for impl in ("pallas", "xla"):
        eng = InferenceEngine(
            cfg, params,
            EngineConfig(max_slots=SLOTS, max_len=256, max_output=512,
                         eos_id=-1, attn_impl=impl),
            mesh=mesh)
        if impl == "pallas":
            assert eng.pallas_fallback is False, eng.pallas_fallback_reason
            assert eng.cfg.attn_impl == "pallas"
        jobs = [Job(job_id=4000 + i, prompt="p",
                    prompt_tokens=[7, 8, 9, 10, 11, 12],
                    arrival_time=0.0) for i in range(SLOTS)]
        toks, _ = eng.run_window(jobs, WINDOW)   # compile window (dropped)
        t0 = time.perf_counter()
        for _ in range(n_timed):                 # same slots: decode only
            more, _ = eng.run_window(jobs, WINDOW)
            for t, m in zip(toks, more):
                t.extend(m)
        dt = time.perf_counter() - t0
        tokens[impl] = toks
        tok_s[impl] = SLOTS * WINDOW * n_timed / dt
    assert tokens["pallas"] == tokens["xla"], (
        "TP pallas decode tokens diverge from TP xla")
    cell = {"pallas_under_mesh": {
        "tp": 2, "pallas_fallback": False, "tokens_identical": True,
        "decode_tok_s": {k: round(v, 1) for k, v in tok_s.items()},
    }}
    print(f"[multi_device] TP2 pallas cell: tokens identical, "
          f"pallas {tok_s['pallas']:.1f} tok/s vs xla "
          f"{tok_s['xla']:.1f} tok/s (CPU interpret — correctness record)")
    return cell


def _workload(n: int, rate: float, seed: int) -> ScaleWorkload:
    """Bimodal short/long lengths, Poisson arrivals — small enough that a
    live CPU run is fast, with ground-truth streams so the SimExecutor
    replay can re-serve the identical requests."""
    rng = np.random.RandomState(seed)
    arrival = np.cumsum(rng.exponential(1.0 / rate, n))
    length = rng.choice([6, 12, 24, 48], n, p=[0.35, 0.35, 0.2, 0.1])
    return ScaleWorkload(
        arrival=arrival.astype(np.float64),
        length=length.astype(np.int64),
        prompt_len=np.full(n, 6, np.int64),
        tenant_id=np.zeros(n, np.int32),
        priority_class=np.zeros(n, np.int16),
        deadline=np.full(n, np.inf))


def _requests(w: ScaleWorkload):
    return [Request.from_workload(r) for r in scale_workload_requests(w)]


def _probe(ex: EngineExecutor, reps: int):
    """Per-pod calibration probes at every (batch, window) serving shape;
    first occurrence per shape pays compile (dropped by the fit)."""
    jid = 10 ** 9
    for node, eng in ex.engines.items():
        for _ in range(reps + 1):
            for batch in (1, SLOTS):
                for window in PROBE_WINDOWS:
                    jobs = [Job(job_id=jid + i, prompt="probe",
                                prompt_tokens=[7, 8, 9, 10, 11, 12],
                                arrival_time=0.0) for i in range(batch)]
                    jid += batch
                    ex.execute(node, jobs, window, now=0.0)
                    for j in jobs:
                        ex.evict(node, j)


def _serve(ex: EngineExecutor, requests, placement: str, costs):
    server = ElisServer(
        FrontendConfig(
            n_nodes=len(ex.engines),
            scheduler=SchedulerConfig(policy="isrtf", window=WINDOW,
                                      batch_size=SLOTS),
            preemption=PreemptionConfig(enabled=True),
            placement=placement,
            node_token_cost=costs if placement == "least_eta" else None,
            observe_in_flight=False,
        ),
        OraclePredictor(),
        ex,
    )
    for r in requests:
        server.submit(r)
    responses = server.drain()
    finished = [r for r in responses if r.ok]
    assert len(finished) == len(responses), (
        f"{len(responses) - len(finished)} requests did not finish")
    m = summarize(finished)
    m["migrations"] = server.frontend.migrations
    return m


def run(smoke: bool = False, quick: bool = False):
    smoke = smoke or quick
    if len(jax.devices()) < 3:
        note = ("skipped: needs >=3 devices — run with XLA_FLAGS="
                "--xla_force_host_platform_device_count=8")
        print(f"[multi_device] {note}")
        return [{"note": note}]
    n, reps, rate = (16, 2, 8.0) if smoke else (48, 4, 6.0)
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_slots=SLOTS, max_len=256, max_output=64,
                        eos_id=-1, respect_job_max=True)
    ex = EngineExecutor(_pods(cfg, params, ecfg))

    # 1. live per-pod calibration --------------------------------------- #
    _probe(ex, reps)
    profs = ex.calibrated_node_profiles(prefix="live-pod")
    overhead_s = float(np.mean(list(ex.node_fit_overhead_s.values())))
    costs = {n_: p.decode_ms_1 / 1000.0 for n_, p in profs.items()}
    rows = [{
        "pods": [
            {"node": n_, "tp": (1 if ex.engines[n_].mesh is None else
                                int(np.asarray(
                                    ex.engines[n_].mesh.devices).size)),
             "decode_ms_1": round(profs[n_].decode_ms_1, 4),
             "batch_slowdown": round(profs[n_].batch_slowdown, 4),
             "fit_overhead_ms": round(
                 ex.node_fit_overhead_s[n_] * 1000, 3)}
            for n_ in sorted(ex.engines)],
        "mean_fit_overhead_ms": round(overhead_s * 1000, 3),
    }]
    print(f"[multi_device] fitted pods: {rows[0]['pods']}")

    # 1b. pallas-under-mesh: shard_map'd decode kernel vs XLA on TP=2 ---- #
    rows.append(_pallas_cell(cfg, params, smoke))

    # 2. live placement comparison (fitted costs drive least_eta) ------- #
    w = _workload(n, rate, seed=7)
    live = {}
    for placement in ("least_jobs", "least_eta"):
        m = _serve(ex, _requests(w), placement, costs)
        live[placement] = m
        rows.append({
            "placement": placement, "n": n,
            "jct_mean_s": round(m["jct_mean"], 3),
            "jct_p99_s": round(m["jct_p99"], 3),
            "queuing_delay_mean_s": round(m["queuing_delay_mean"], 3),
            "migrations": m["migrations"],
        })
        print(f"[multi_device] live {placement}: "
              f"mean JCT {m['jct_mean']:.3f}s  p99 {m['jct_p99']:.3f}s")

    # per-pod separability + trace bounds (the smoke guard's teeth): a
    # recompile storm on one pod must be visible *on that pod*
    per = ex.node_counters()
    agg = ex.counters()
    assert sorted(per) == [0, 1]
    for n_, eng in ex.engines.items():
        assert per[n_]["prefill_traces"] <= eng.prefill_shape_bound(), per
        assert per[n_]["decode_traces"] <= (
            len(PROBE_WINDOWS) * eng.decode_batch_buckets()), per
        assert per[n_]["windows_executed"] > 0, (
            f"pod {n_} never served a window — not a live cluster")
    for k in ("prefill_traces", "prefill_dispatches", "decode_traces",
              "decode_dispatches", "windows_executed"):
        assert agg[k] == per[0][k] + per[1][k], (k, agg, per)
    rows.append({"node_counters": {str(k): v for k, v in per.items()}})

    # 3. sim replay of the fitted cluster ------------------------------- #
    sim_server = ElisServer(
        FrontendConfig(
            n_nodes=2,
            scheduler=SchedulerConfig(policy="isrtf", window=WINDOW,
                                      batch_size=SLOTS),
            preemption=PreemptionConfig(enabled=True),
            placement="least_eta",
            node_token_cost=costs,
            observe_in_flight=False,
        ),
        OraclePredictor(),
        SimExecutor(profs[0], node_profiles=profs,
                    sched_overhead_s=overhead_s),
    )
    for r in _requests(w):
        sim_server.submit(r)
    sim_m = summarize([r for r in sim_server.drain() if r.ok])
    live_jct = live["least_eta"]["jct_mean"]
    ratio = sim_m["jct_mean"] / max(live_jct, 1e-9)
    rows.append({
        "sim_replay": {
            "sim_jct_mean_s": round(sim_m["jct_mean"], 3),
            "live_jct_mean_s": round(live_jct, 3),
            "live_vs_sim_ratio": round(ratio, 3),
        }})
    print(f"[multi_device] sim replay: {sim_m['jct_mean']:.3f}s vs live "
          f"{live_jct:.3f}s (ratio {ratio:.2f})")
    band = 3.0 if smoke else 1.5
    assert 1.0 / band <= ratio <= band, (
        f"fitted sim replay {ratio:.2f}x off live (band {band}x)")

    # 4. 100x scale replay through repro.simulate.scale ----------------- #
    w100 = _workload(100 * n, rate, seed=11)
    scfg = ScaleSimConfig(
        model=profs[0], node_profiles={0: profs[0], 1: profs[1]},
        policy="isrtf", predictor="oracle", n_nodes=2, batch_size=SLOTS,
        window=WINDOW, placement="least_eta", sched_overhead_s=overhead_s)
    res = ScaleSimulator(scfg).run(w100)
    sm = res.metrics()
    assert sm["n_finished"] == w100.n, sm
    rows.append({
        "scale_replay_100x": {
            "n_requests": int(w100.n),
            "jct_mean_s": round(float(sm["jct_mean"]), 3),
            "jct_p99_s": round(float(sm["jct_p99"]), 3),
            "n_windows": int(sm["n_windows"]),
            "sim_requests_per_s": round(float(sm["requests_per_s"]), 1),
        }})
    print(f"[multi_device] 100x scale replay: {w100.n} requests, "
          f"mean JCT {sm['jct_mean']:.3f}s "
          f"({sm['requests_per_s']:.0f} sim req/s)")

    save_results("multi_device", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run + assertions (CI multi-device guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(smoke=args.smoke and not args.full)
    if not args.smoke and "note" not in rows[0]:
        # regenerate the committed evidence only on a deliberate CLI run
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
