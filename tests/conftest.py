import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dry-run-only, set inside repro.launch.dryrun before jax init).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests use hypothesis; fall back to the bundled minimal shim when
# the real package is absent (containers without network access).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

import jax

jax.config.update("jax_enable_x64", False)
