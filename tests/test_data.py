"""Data pipeline: tokenizer, workload, arrivals, dataset construction."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import (
    GammaArrivals,
    HashTokenizer,
    WorkloadGenerator,
    build_step_samples,
    exponential_loglik,
    fit_gamma,
    gamma_loglik,
    iqr_filter,
    make_predictor_dataset,
    pad_batch,
    split_622,
)
from repro.data.dataset import WINDOW
from repro.data.tokenizer import CLS_ID, N_SPECIAL, SEP_ID
from repro.data.workload import TOPICS, similarity_probe_sets


def test_tokenizer_deterministic_and_in_range():
    tok = HashTokenizer(vocab_size=1000)
    a = tok.encode("the quick brown fox")
    b = tok.encode("the quick brown fox")
    assert a == b
    assert all(N_SPECIAL <= t < 1000 for t in a)
    assert tok.encode("THE")[0] == tok.encode("the")[0]


@given(st.text(alphabet=st.characters(min_codepoint=33, max_codepoint=126),
               min_size=1, max_size=30))
@settings(max_examples=100, deadline=None)
def test_tokenizer_any_word(word):
    tok = HashTokenizer()
    tid = tok.token_id(word)
    assert N_SPECIAL <= tid < tok.vocab_size


def test_workload_length_signal_exists():
    """Latents must determine expected length (what the predictor learns)."""
    gen = WorkloadGenerator(seed=0)
    reqs = gen.sample_requests(3000)
    by_task = {}
    for r in reqs:
        by_task.setdefault(r.task, []).append(r.true_output_len)
    means = {t: np.mean(v) for t, v in by_task.items()}
    assert means["story"] > means["explain"] > means["factual"] > means["yesno"]
    # verbosity modifier is visible too
    by_verb = {}
    for r in reqs:
        by_verb.setdefault(r.verbosity, []).append(r.true_output_len)
    assert np.mean(by_verb["verbose"]) > np.mean(by_verb["terse"])


def test_workload_reproducible():
    a = WorkloadGenerator(seed=42).sample_requests(20)
    b = WorkloadGenerator(seed=42).sample_requests(20)
    assert [r.prompt for r in a] == [r.prompt for r in b]
    assert [r.true_output_len for r in a] == [r.true_output_len for r in b]


def test_gamma_fit_recovers_params():
    rng = np.random.RandomState(0)
    iv = GammaArrivals().sample_intervals(30_000, rng)
    a, s = fit_gamma(iv)
    assert abs(a - 0.73) < 0.05
    assert abs(s - 10.41) < 0.8


def test_gamma_beats_poisson_on_bursty_trace():
    """Paper Fig. 4: Gamma fits the FabriX-like trace better than Poisson."""
    rng = np.random.RandomState(1)
    iv = GammaArrivals().sample_intervals(10_000, rng)
    a, s = fit_gamma(iv)
    assert gamma_loglik(iv, a, s) > exponential_loglik(iv)


def test_rate_scaled_mean():
    g = GammaArrivals().rate_scaled(2.0)  # 2 req/s
    rng = np.random.RandomState(2)
    iv = g.sample_intervals(20_000, rng)
    assert abs(iv.mean() - 0.5) < 0.02
    assert g.alpha == pytest.approx(0.73)  # burstiness preserved


def test_step_samples_window_structure():
    gen = WorkloadGenerator(seed=3)
    reqs = [r for r in gen.sample_requests(50) if r.true_output_len > 120][:5]
    samples = build_step_samples(reqs, max_steps=4)
    for s in samples:
        assert s.remaining >= 1
        assert s.tokens[0] == CLS_ID
        assert SEP_ID in s.tokens
    by_req = {}
    for s in samples:
        by_req.setdefault(s.request_id, []).append(s)
    for rid, group in by_req.items():
        group.sort(key=lambda s: s.step)
        rem = [s.remaining for s in group]
        assert all(rem[i] - rem[i + 1] == WINDOW for i in range(len(rem) - 1))


def test_iqr_filter_and_split():
    tr, va, te = make_predictor_dataset(300, seed=0)
    n = len(tr) + len(va) + len(te)
    assert abs(len(tr) / n - 0.6) < 0.02
    assert abs(len(va) / n - 0.2) < 0.02


def test_pad_batch_shapes():
    gen = WorkloadGenerator(seed=4)
    samples = build_step_samples(gen.sample_requests(10))
    b = pad_batch(samples[:8], max_len=64)
    assert b["tokens"].shape == (8, 64)
    assert b["mask"].shape == (8, 64)
    assert (b["labels"] > 0).all()


def test_similarity_probe_sets_disjoint_topics():
    sim, dis, tok = similarity_probe_sets(50, seed=0)
    weather = set(TOPICS["weather"]["words"])
    assert all(set(s.split()) <= weather for s in sim)
    assert all(not (set(s.split()) & weather) for s in dis)
