"""Learning-to-rank scheduling: {regression, rank, rank+conformal-mean}
x {isrtf, fcfs} under bursty and multi-tenant regimes.

ISRTF consumes only the *order* of predicted remaining lengths — the
magnitude is scheduler-irrelevant (scale-invariance of shortest-first).
A pairwise-trained ranking head (``repro.models.objective.RankingConfig``,
served through ``repro.core.predictor.RankedPredictor``) optimises that
order directly, while the regression head keeps the calibrated magnitudes
the cluster layer's predicted-work accounting needs.  This benchmark
quantifies what the split buys at **equal encoder budget**: one
regression-only BGE and one two-head BGE, same architecture, same data,
same training steps.

Arms per regime (``rank_by`` is the pool-ordering source,
``SchedulerConfig.rank_by``; load accounting stays on the mean always):

* ``oracle``/isrtf — the ideal ordering bound (gap framing)
* ``bge``/fcfs and ``ranked``/fcfs — no-ordering references (FCFS never
  consults scores, so these isolate predictor-side effects ~ none)
* ``bge``/isrtf, rank_by=magnitude — the regression baseline
* ``ranked``/isrtf, rank_by=rank_score — the ranking head orders the pool
* ``ranked``+conformal/isrtf, rank_by=rank_score — the conformal wrapper
  composed outside the ranked predictor.  On a single node with no risk
  quantile this cell is trace-identical to the uncalibrated one BY
  DESIGN (conformal builds quantile ladders, passes the mean through,
  and never touches ``rank_score``) — the committed identical numbers
  document that composing calibration does not perturb rank ordering

A standalone τ probe reports held-out Kendall-τ for both models (the
regression head and the rank head of the two-head model) — the committed
guard is ``tau_rank >= tau_regression``: trained on ordering, the rank
head must not order *worse* than the magnitude regressor it rides with.
The non-smoke acceptance bar: the rank-ordered ISRTF closes part of the
regression→oracle JCT gap (lower mean JCT than the regression baseline)
in at least one regime.

``RankedPredictor`` keeps learning online during every ranked cell (pairs
harvested from completed jobs; cancelled/expired jobs are censored and
never form pairs — tests/test_ranking.py pins that path).  Each ranked
cell snapshots and restores the shared two-head params so cells stay
independent.

Emits ``BENCH_rank_sched.json`` at the repo root (committed).  ``--smoke``
trains both models, runs the τ probe + one bursty cell pair, and asserts
the τ guard — the CI guard for the ranking subsystem.

    PYTHONPATH=src python -m benchmarks.rank_sched [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import BGEPredictor, PredictorConfig, RankingConfig
from repro.data import make_predictor_dataset
from repro.models.encoder import EncoderArchConfig
from repro.simulate import ExperimentConfig, run_experiment

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_rank_sched.json")

#: training budget shared by BOTH models (the equal-budget contract).
#: Deliberately brief — an undertrained regressor orders noisily, which is
#: exactly where a direct ranking objective has leverage (same reasoning
#: as predictor_calibration.train_bge's 120-step regime)
TRAIN_STEPS = 150

REGIMES = ("bursty", "multi_tenant")


def _cfg() -> PredictorConfig:
    return PredictorConfig(
        encoder=EncoderArchConfig(d_model=64, n_heads=2, n_layers=2,
                                  d_ff=128, max_len=128),
        n_fc_layers=4, fc_hidden=128, max_len=128, lr=3e-4,
    )


def train_pair(seed: int = 0, num_steps: int = TRAIN_STEPS):
    """One regression-only and one two-head BGE at the same encoder
    budget (identical architecture / data / steps / batch size).
    Returns ``(reg, two, tau_probe_row)``."""
    tr, _, te = make_predictor_dataset(500, seed=seed, max_len=128,
                                       max_steps=4)
    reg = BGEPredictor(_cfg(), seed=seed)
    reg.fit(tr, num_steps=num_steps, batch_size=32)
    base = _cfg()
    two = BGEPredictor(
        PredictorConfig(
            encoder=base.encoder, n_fc_layers=base.n_fc_layers,
            fc_hidden=base.fc_hidden, max_len=base.max_len, lr=base.lr,
            ranking=RankingConfig()),
        seed=seed)
    two.fit(tr, num_steps=num_steps, batch_size=32)
    probe = {
        "probe": "kendall_tau",
        "train_steps": num_steps,
        "n_test_samples": len(te),
        "tau_regression": round(reg.evaluate(te)["kendall_tau"], 4),
        "tau_two_head_regression": round(
            two.evaluate(te)["kendall_tau"], 4),
        "tau_rank": round(two.evaluate_rank(te)["kendall_tau"], 4),
    }
    return reg, two, probe


def one_cell(regime: str, predictor: str, policy: str, rank_by: str,
             calibrate: str, n_requests: int, seeds: List[int],
             *, bge=None) -> Dict:
    """One sweep cell, averaged over seeds.  Ranked cells snapshot the
    shared two-head params around each run — ``RankedPredictor`` learns
    online and would otherwise leak updates across cells."""
    agg = {"jct_mean": [], "jct_p99": [], "n_unfinished": []}
    for seed in seeds:
        cfg = ExperimentConfig(
            model="vic", policy=policy, predictor=predictor,
            calibrate=calibrate, rank_by=rank_by,
            n_requests=n_requests, batch_size=4, rps_multiple=1.5,
            seed=seed,
        )
        if regime == "bursty":
            cfg.arrivals = "bursty"
            cfg.burst_size = 24
        elif regime == "multi_tenant":
            cfg.scenario = "multi_tenant_slo"
        else:
            raise ValueError(f"unknown regime {regime!r} "
                             f"(have {list(REGIMES)})")
        snapshot = bge.params if bge is not None else None
        try:
            # streaming aggregation keeps peak memory flat across the sweep
            m = run_experiment(cfg, bge=bge, stream_metrics=True)
        finally:
            if snapshot is not None:
                bge.params = snapshot
        if regime == "bursty":
            # bursty has no deadlines: every admitted job must finish
            # (assert_drained already ran inside run_experiment)
            assert m["n_unfinished"] == 0, m
        agg["jct_mean"].append(m["jct_mean"])
        agg["jct_p99"].append(m["jct_p99"])
        agg["n_unfinished"].append(m["n_unfinished"])
    return {
        "regime": regime,
        "predictor": predictor,
        "policy": policy,
        "rank_by": rank_by,
        "calibrate": calibrate,
        "n_requests": n_requests,
        "seeds": seeds,
        "jct_mean": round(float(np.mean(agg["jct_mean"])), 3),
        "jct_p99": round(float(np.mean(agg["jct_p99"])), 3),
        "n_unfinished": int(np.sum(agg["n_unfinished"])),
    }


def cell(rows: List[Dict], **want) -> Optional[Dict]:
    for r in rows:
        if all(r.get(k) == v for k, v in want.items()):
            return r
    return None


#: (predictor, policy, rank_by, calibrate) arms swept per regime
ARMS = [
    ("oracle", "isrtf", "magnitude", "none"),
    ("bge", "fcfs", "magnitude", "none"),
    ("ranked", "fcfs", "magnitude", "none"),
    ("bge", "isrtf", "magnitude", "none"),
    ("ranked", "isrtf", "rank_score", "none"),
    ("ranked", "isrtf", "rank_score", "conformal"),
]


def run(smoke: bool = False, quick: bool = False) -> List[Dict]:
    smoke = smoke or quick  # benchmarks.run harness passes quick=
    if smoke:
        n_requests, seeds = 60, [0]
        regimes = ["bursty"]
        arms = [a for a in ARMS
                if a[:2] in (("bge", "isrtf"), ("ranked", "isrtf"))
                and a[3] == "none"]
    else:
        n_requests, seeds = 120, [0, 1]
        regimes = list(REGIMES)
        arms = ARMS

    reg, two, probe = train_pair()
    rows: List[Dict] = [probe]
    # -- the committed τ guard: trained on ordering, the rank head must
    #    not order worse than the equal-budget magnitude regressor ------- #
    assert probe["tau_rank"] >= probe["tau_regression"], probe

    for regime in regimes:
        for predictor, policy, rank_by, calibrate in arms:
            rows.append(one_cell(
                regime, predictor, policy, rank_by, calibrate,
                n_requests, seeds,
                bge={"bge": reg, "ranked": two}.get(predictor)))
            print(rows[-1], flush=True)

    if not smoke:
        # -- the acceptance bar: rank-ordered ISRTF closes part of the
        #    regression→oracle JCT gap in at least one regime (fixed
        #    seeds, so this is a regression guard, not a coin flip) ------ #
        wins = []
        for regime in regimes:
            base = cell(rows, regime=regime, predictor="bge",
                        policy="isrtf")
            ranked = [r for r in rows
                      if r.get("regime") == regime
                      and r.get("rank_by") == "rank_score"]
            if min(r["jct_mean"] for r in ranked) < base["jct_mean"]:
                wins.append(regime)
        assert wins, (
            "rank-ordered ISRTF never beat the regression baseline on "
            f"mean JCT: {rows}")

    save_results("rank_sched", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="τ probe + one bursty cell pair only "
                         "(CI ranking-subsystem guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(smoke=args.smoke and not args.full)
    if not args.smoke:
        # regenerate the committed evidence only on a deliberate CLI run
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    probe = rows[0]
    print(f"[rank_sched] held-out Kendall-τ: regression "
          f"{probe['tau_regression']:.3f} -> rank head "
          f"{probe['tau_rank']:.3f}")
    for regime in sorted({r["regime"] for r in rows if "regime" in r}):
        oracle = cell(rows, regime=regime, predictor="oracle")
        base = cell(rows, regime=regime, predictor="bge", policy="isrtf")
        ranked = [r for r in rows if r.get("regime") == regime
                  and r.get("rank_by") == "rank_score"]
        if not (oracle and base and ranked):
            continue
        best = min(ranked, key=lambda r: r["jct_mean"])
        gap = base["jct_mean"] - oracle["jct_mean"]
        closed = base["jct_mean"] - best["jct_mean"]
        print(f"[rank_sched] {regime}: regression {base['jct_mean']:.2f}s "
              f"-> rank {best['jct_mean']:.2f}s "
              f"(calibrate={best['calibrate']}; oracle "
              f"{oracle['jct_mean']:.2f}s; "
              f"{100 * closed / gap if gap > 0 else 0:.0f}% of gap closed)")


if __name__ == "__main__":
    main()
