"""Dry-run/roofline infrastructure: HLO collective parser, cost
extrapolation, int8-KV quantization + kernel, launchers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.dryrun import collective_bytes
from repro.launch.costprobe import _axpy, _extrapolate
from repro.configs import get_config
from repro.models.layers import dequantize_kv, quantize_kv


# --------------------------------------------------------------------------- #
# HLO collective parser
# --------------------------------------------------------------------------- #

HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ar = bf16[16,1024]{1,0} all-reduce(%p0), replica_groups={{0,1}}
  %ag = f32[4,256]{1,0} all-gather(%p0), dimensions={0}
  %rs = bf16[8,512]{1,0} reduce-scatter(%ar), dimensions={0}
  %tup = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%ag, %ag)
  %cp = s8[100]{0} collective-permute(%p0)
  // %comment = bf16[9999,9999]{1,0} all-reduce(%p0)  <- must be ignored
  %mm = bf16[16,1024]{1,0} dot(%p0, %p0)
}
"""


def test_collective_parser_counts_each_op():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 1024 * 2
    assert out["all-gather"] == 4 * 256 * 4
    assert out["reduce-scatter"] == 8 * 512 * 2
    assert out["all-to-all"] == 2 * (2 * 2 * 4)  # tuple: both outputs
    assert out["collective-permute"] == 100 * 1
    assert out["count"] == 5
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_collective_parser_ignores_non_collectives():
    out = collective_bytes("%x = bf16[4,4]{1,0} dot(%a, %b)")
    assert out["total"] == 0 and out["count"] == 0


# --------------------------------------------------------------------------- #
# Cost extrapolation (scan correction)
# --------------------------------------------------------------------------- #


def _cost(f, b, c):
    return {"flops": f, "bytes_accessed": b, "collective_bytes": c}


def test_axpy():
    out = _axpy(_cost(10, 20, 30), _cost(1, 2, 3), 1.0, 2.0)
    assert out == _cost(12, 24, 36)


def test_extrapolate_linear_dense():
    cfg = get_config("yi-6b")  # 32 layers
    # cost(L) = 5 + 3L  ->  c2 = 11, c4 = 17, want cost(32) = 101
    got = _extrapolate(cfg, [_cost(11, 11, 11), _cost(17, 17, 17)])
    assert got["flops"] == pytest.approx(5 + 3 * 32)


def test_extrapolate_hybrid_group_tail():
    cfg = get_config("zamba2-7b")  # 81L = 13 groups*6 + 3 tail, attn_every=6
    # model: cost = a + G*g + T*t with a=7, g=11, t=2
    a, g, t = 7.0, 11.0, 2.0
    c12 = _cost(*[a + 2 * g] * 3)             # G=2, T=0
    c15 = _cost(*[a + 2 * g + 3 * t] * 3)     # G=2, T=3
    c24 = _cost(*[a + 4 * g] * 3)             # G=4, T=0
    got = _extrapolate(cfg, [c12, c15, c24])
    assert got["flops"] == pytest.approx(a + 13 * g + 3 * t)


def test_extrapolate_audio_joint():
    cfg = get_config("whisper-large-v3")  # enc=dec=32
    # cost(k) = 4 + 6k
    got = _extrapolate(cfg, [_cost(16, 16, 16), _cost(28, 28, 28)])
    assert got["flops"] == pytest.approx(4 + 6 * 32)


# --------------------------------------------------------------------------- #
# int8 KV quantization + kernel
# --------------------------------------------------------------------------- #


def test_quantize_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32)) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16)
    back = dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02  # 1/127 quantization grid


def test_flash_decode_int8_matches_dequantized_reference():
    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, KH, L, D = 2, 4, 2, 256, 32
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kf = jax.random.normal(ks[1], (B, L, KH, D))
    vf = jax.random.normal(ks[2], (B, L, KH, D))
    kq, ksc = quantize_kv(kf)
    vq, vsc = quantize_kv(vf)
    kv_len = jnp.array([100, 256])
    out = ops.flash_decode_int8(q, kq, vq, ksc, vsc, kv_len=kv_len,
                                q_offset=kv_len - 1)
    want = ref.reference_decode_attention(
        q, dequantize_kv(kq, ksc, jnp.float32),
        dequantize_kv(vq, vsc, jnp.float32),
        kv_len=kv_len, q_offset=kv_len - 1,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # and close to the unquantized attention (quantization error only)
    exact = ref.reference_decode_attention(q, kf, vf, kv_len=kv_len,
                                           q_offset=kv_len - 1)
    assert float(jnp.max(jnp.abs(out - exact))) < 0.05


# --------------------------------------------------------------------------- #
# Workload phase structure (the Fig-2b mechanism)
# --------------------------------------------------------------------------- #


def test_response_phases_reveal_progress():
    from repro.data import WorkloadGenerator
    from repro.data.workload import CLOSING_WORDS, OPENING_WORDS

    gen = WorkloadGenerator(seed=0)
    tok = gen.tok
    open_ids = {tok.token_id(w) for w in OPENING_WORDS}
    close_ids = {tok.token_id(w) for w in CLOSING_WORDS}
    reqs = [r for r in gen.sample_requests(200) if r.true_output_len > 120]
    assert reqs
    for r in reqs[:20]:
        head = set(r.output_tokens[:10])
        tail = set(r.output_tokens[-15:-1])
        assert head <= open_ids
        assert tail <= close_ids


def test_generate_cli_roundtrip(tmp_path):
    import json
    import subprocess
    import sys

    out = tmp_path / "trace.jsonl"
    subprocess.run(
        [sys.executable, "-m", "repro.launch.generate", "--n", "5",
         "--rate", "2.0", "--out", str(out)],
        check=True, env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    recs = [json.loads(l) for l in open(out)]
    assert len(recs) == 5
    times = [r["arrival_time"] for r in recs]
    assert times == sorted(times)
    assert all(r["max_tokens"] >= 1 for r in recs)
