"""Token samplers."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0            # 0 = full softmax


def sample(logits: jnp.ndarray, key, cfg: SamplerConfig) -> jnp.ndarray:
    """logits (B, V) -> (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        top, _ = jax.lax.top_k(logits, cfg.top_k)
        kth = top[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
