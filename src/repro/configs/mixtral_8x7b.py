"""Mixtral-8x7B [arXiv:2401.04088] — sparse MoE, 8 experts top-2, SWA.

32L, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000.
Sliding-window attention (4096) makes long_500k decode native.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="mixtral-8x7b",
        family="moe",
        source="arXiv:2401.04088",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1_000_000.0,
        attention_type="swa",
        swa_window=4096,
        long_context_mode="native",
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336,
                      norm_topk_prob=True),
        max_position_embeddings=32768,
    )
)
