"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips × 819 GB/s HBM)
    collective term = collective_bytes / (chips × 50 GB/s ICI)

cost_analysis() on the SPMD-partitioned module reports *per-device* FLOPs
and bytes, and the collective-byte parser sums per-device payloads — so the
terms are per-chip times directly (no extra division); "chips" below refers
to using per-device numbers, not dividing global numbers.

Also derives MODEL_FLOPS = 6·N·D (dense; N_active for MoE) and the useful-
compute ratio MODEL_FLOPS / (HLO_FLOPs × chips), which exposes remat /
padding / replication waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.shapes import SHAPES

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
COSTMODEL_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                             "costmodel")


def _corrected_costs(arch: str, shape: str, tag: str = "") -> Optional[Dict]:
    """Scan-corrected per-device costs from the unrolled probe extrapolation
    (see repro/launch/costprobe.py) — preferred over the rolled-scan HLO
    numbers, which count loop bodies once."""
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(COSTMODEL_DIR, f"{arch}_{shape}_single{suffix}.json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    if rec.get("status") != "ok":
        return None
    return rec["corrected"]


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the whole step (global, all chips)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens  # fwd 2ND + bwd 4ND
    if shape.kind == "prefill":
        tokens = shape.global_batch * min(
            shape.seq_len, cfg.max_position_embeddings
            if cfg.family == "audio" else shape.seq_len)
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    flops_dev = rec["flops"]           # per-device (SPMD module)
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collectives"]["total"]
    corrected = False
    if rec["mesh"] == "single":
        corr = _corrected_costs(rec["arch"], rec["shape"],
                                rec.get("tag", ""))
        if corr:
            flops_dev = corr["flops"]
            bytes_dev = corr["bytes_accessed"]
            coll_dev = corr["collective_bytes"]
            corrected = True

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops_dev * chips, 1.0)
    bound_time = max(terms.values())
    # fraction of the roofline bound that is useful compute
    mfu_bound = (mf / chips / PEAK_FLOPS_BF16) / max(bound_time, 1e-30)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "chips": chips,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_compute_ratio": useful,
        "roofline_mfu_bound": mfu_bound,
        "scan_corrected": corrected,
        "peak_gb": rec["memory_analysis"].get("peak_memory_in_bytes", 0)
        / 2**30,
        "fits_hbm16": rec["memory_analysis"].get("peak_memory_in_bytes", 0)
        <= 16 * 2**30,
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("overlap/shrink collectives: reduce-scatter instead of "
                "all-reduce, shard activations to kill all-gathers")
    if d == "memory":
        if row["shape"].startswith("decode") or row["shape"] == "long_500k":
            return ("KV bytes dominate: quantize KV (int8), GQA-style head "
                    "reduction, or larger per-chip batch to amortise weights")
        return "fuse/remat to cut activation traffic; bf16 everywhere"
    return "increase per-chip arithmetic intensity (bigger tiles, less pad)"


def load_records(tag: str = "") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") == tag:
            recs.append(r)
    return recs


def run(quick: bool = False) -> List[Dict]:
    rows = []
    for rec in load_records():
        a = analyze_record(rec)
        if a:
            a["suggestion"] = suggestion(a)
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful ratio | peak GB | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_compute_ratio']:.2f} | {r['peak_gb']:.1f} "
            f"| {'yes' if r['fits_hbm16'] else 'NO'} |"
        )
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
    from benchmarks.common import save_results

    save_results("roofline", rows)
