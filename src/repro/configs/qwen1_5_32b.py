"""Qwen1.5-32B [hf:Qwen/Qwen1.5 family] — large dense decoder with QKV bias.

64L, d_model 5120, 40 heads (GQA kv=40 => MHA-width KV), d_ff 27392,
vocab 152064.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen1.5-32b",
        family="dense",
        source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment)",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        attention_type="full",
        long_context_mode="sliding_window",
        max_position_embeddings=32768,
    )
)
