"""Shared test doubles for the scheduling test suites."""
import numpy as np

from repro.core import OraclePredictor


class CountingOracle(OraclePredictor):
    """Oracle with a batched entry point, counting dispatches like the BGE
    predictor's ``predict_jobs`` path."""

    def __init__(self):
        self.dispatches = 0

    def predict_jobs(self, jobs):
        self.dispatches += 1
        return np.array([float(j.true_remaining) for j in jobs])
