"""Zamba2-7B [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared attention.

81 Mamba2 layers, d_model 3584, ssm_state 64; a *shared* (weight-tied)
attention+MLP block (32 heads, kv=32, d_ff 14336) is applied every 6 backbone
layers (13 invocations + 3 tail layers).  Sub-quadratic: long_500k runs with
the SSM state + the shared-attention KV limited to a sliding window.
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="zamba2-7b",
        family="hybrid",
        source="arXiv:2411.15242",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        head_dim=112,
        rope_theta=10000.0,
        attention_type="swa",
        swa_window=4096,
        long_context_mode="native",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                      chunk_size=256),
        hybrid=HybridConfig(attn_every=6, n_shared_blocks=1),
        max_position_embeddings=1 << 20,
    )
)
