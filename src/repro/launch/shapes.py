"""The four assigned input shapes and per-(arch, shape) abstract inputs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, zero allocation.  Decode shapes
lower ``serve_step`` (ONE token + a KV cache of seq_len); train lowers the
full optimizer step; prefill lowers the prompt pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T

#: long_500k carve-in window for pure full-attention archs (DESIGN.md §4)
LONG_CONTEXT_WINDOW = 8192


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    s.name: s
    for s in [
        InputShape("train_4k", 4_096, 256, "train"),
        InputShape("prefill_32k", 32_768, 32, "prefill"),
        InputShape("decode_32k", 32_768, 128, "decode"),
        InputShape("long_500k", 524_288, 1, "decode"),
    ]
}


def supported(cfg, shape: InputShape) -> bool:
    """The one skip: whisper's decoder is positionally bounded (448)."""
    if shape.name == "long_500k" and cfg.long_context_mode == "unsupported":
        return False
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _decoder_seq(cfg, seq_len: int) -> int:
    """Whisper's decoder length is architecturally capped."""
    if cfg.family == "audio":
        return min(seq_len, cfg.max_position_embeddings)
    return seq_len


def input_specs(cfg, shape: InputShape, *, kv_dtype: Optional[str] = None) -> Dict:
    """Abstract batch (+ cache for decode) for one (arch, shape) pair.

    ``kv_dtype="int8"`` builds the quantized-KV cache variant (§Perf).
    """
    b = shape.global_batch
    s = _decoder_seq(cfg, shape.seq_len)
    tok = jnp.int32
    out: Dict = {}

    if shape.kind == "train":
        text_s = s
        if cfg.family == "vlm":
            text_s = s - cfg.frontend_tokens
            out["embeds"] = _sds((b, cfg.frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
            out["positions"] = _sds((3, b, s), tok)
        if cfg.family == "audio":
            out["frames"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        out["tokens"] = _sds((b, text_s), tok)
        out["labels"] = _sds((b, s), tok)
        return out

    if shape.kind == "prefill":
        text_s = s
        if cfg.family == "vlm":
            text_s = s - cfg.frontend_tokens
            out["embeds"] = _sds((b, cfg.frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
            out["positions"] = _sds((3, b, s), tok)
        if cfg.family == "audio":
            out["frames"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        out["tokens"] = _sds((b, text_s), tok)
        out["cache"] = T.abstract_cache(cfg, b, s,
                                        sliding_window=_window(cfg, shape),
                                        kv_dtype=kv_dtype)
        return out

    # decode
    out["tokens"] = _sds((b, 1), tok)
    out["cache"] = T.abstract_cache(cfg, b, shape.seq_len,
                                    sliding_window=_window(cfg, shape),
                                    kv_dtype=kv_dtype)
    return out


def _window(cfg, shape: InputShape) -> Optional[int]:
    """Sliding-window carve-in: only for long_500k on full-attention archs."""
    if shape.name == "long_500k" and cfg.long_context_mode == "sliding_window":
        return LONG_CONTEXT_WINDOW
    return None
