"""Scale-out simulation benchmark: million-request workloads on CPU.

Exercises the ``repro.simulate.scale`` vectorized event core on the three
registered traffic scenarios (diurnal / multi_tenant_slo / flash_crowd) and
documents the two promises the subsystem makes:

* **throughput** — one million requests through a 16-node cluster in
  minutes on a laptop-class CPU (the exact ``ELISFrontend`` loop is
  ~100x slower at this scale), with peak RSS reported;
* **fidelity** — on a validation slice replayed through both loops, the
  fast path is *trace-identical* to the exact frontend on
  coalescing-safe configs (oracle predictor), so the committed
  mean-JCT / p99 deltas are exactly zero; the statistical tolerance that
  remains is the streaming quantile sketch's ~0.3% relative bucket error
  (p50/p99 only; means are exact).

Emits ``BENCH_sim_scale.json`` at the repo root (committed).  ``--smoke``
runs a ~50k-request slice with the same fidelity + throughput-floor
assertions as a CI guard against fast-path regressions.

    PYTHONPATH=src python -m benchmarks.sim_scale [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import time
from typing import Dict, List

import numpy as np

from repro.data.workload import build_scale_workload
from repro.simulate.scale import (
    FINISHED,
    ScaleSimConfig,
    ScaleSimulator,
    run_exact_reference,
)

from benchmarks.common import save_results

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sim_scale.json")

#: paper Fig-7 style 16-node H100-speed cluster.  Sustained capacity is
#: batch * 1000/decode_ms(batch) * n_nodes / mean_length ~= 171 req/s at
#: batch 32 (mean response ~163 tokens); batch 32 also halves the
#: window count vs batch 16 — the simulated-window total is work-bound
#: at total_tokens / (batch * window), independent of node count.
CLUSTER = dict(model="vic", n_nodes=16, batch_size=32, hw_speedup=3.35,
               policy="isrtf", predictor="oracle",
               placement="least_predicted_work")

#: mean arrival rate (req/s) for the scenario workloads — ~59% of
#: sustained capacity, so the diurnal peaks (1.7x the mean) ride right at
#: capacity: queues build and drain every cycle (p99 JCT is hours while
#: p50 stays seconds) without the unbounded backlog of a mean-rate
#: oversubscription, which would make per-window scoring O(backlog)
RATE = 100.0


def peak_rss_mb() -> float:
    """Lifetime peak resident set of this process (Linux: ru_maxrss in KB)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def scenario_cell(scenario: str, n: int, rate: float, seed: int = 0) -> Dict:
    """Run one scenario through the fast path; report throughput + metrics."""
    rng = np.random.RandomState(seed)
    w = build_scale_workload(scenario, n, rate, rng)
    sim = ScaleSimulator(ScaleSimConfig(seed=seed, **CLUSTER))
    res = sim.run(w)
    m = res.metrics()
    row = {
        "cell": f"scale_{scenario}",
        "scenario": scenario,
        "n_requests": n,
        "rate_rps": rate,
        "seed": seed,
        **{k: CLUSTER[k] for k in ("n_nodes", "batch_size", "policy",
                                   "placement")},
        "wall_s": round(m["wall_s"], 2),
        "requests_per_s": round(m["requests_per_s"], 1),
        "peak_rss_mb": round(peak_rss_mb(), 1),
        "n_finished": m["n_finished"],
        "n_expired": m["n_expired"],
        "n_windows": m["n_windows"],
        "n_coalesced_windows": m["n_coalesced_windows"],
        "jct_mean": round(float(m["jct_mean"]), 3),
        "jct_p50": round(float(m["jct_p50"]), 3),
        "jct_p99": round(float(m["jct_p99"]), 3),
        "queuing_delay_mean": round(float(m["queuing_delay_mean"]), 3),
    }
    if len(m["tenants"]) > 1:
        row["tenants"] = {
            t: {k: (round(float(tm[k]), 3) if isinstance(tm[k], float)
                    else tm[k])
                for k in ("n", "jct_mean", "jct_p99", "slo_attainment")
                if k in tm}
            for t, tm in m["tenants"].items()
        }
        row["fairness_jct"] = round(float(m["fairness_jct"]), 3)
    return row


def fidelity_cell(n_slice: int, seed: int = 0) -> Dict:
    """Replay a diurnal validation slice through both loops and diff them.

    The oracle configs the fast path supports are bit-exact by design
    (identical IEEE op order), so every delta below is asserted == 0; the
    row commits the evidence."""
    rng = np.random.RandomState(seed)
    w = build_scale_workload("diurnal", n_slice, RATE, rng)
    cfg = ScaleSimConfig(seed=seed, **CLUSTER)
    fast = ScaleSimulator(cfg).run(w)
    exact = run_exact_reference(cfg, w)

    fmask = fast.state == FINISHED
    emask = exact.state == FINISHED
    assert (fmask == emask).all(), "finished sets diverge"
    jf = fast.finish[fmask] - w.arrival[fmask]
    je = exact.finish[emask] - w.arrival[emask]
    mean_delta_pct = 100.0 * abs(jf.mean() - je.mean()) / je.mean()
    p99_delta_pct = 100.0 * abs(np.percentile(jf, 99) - np.percentile(je, 99)
                                ) / np.percentile(je, 99)
    max_finish_delta = float(np.abs(fast.finish[fmask]
                                    - exact.finish[emask]).max())
    trace_identical = bool(
        (fast.state == exact.state).all()
        and np.array_equal(fast.finished_order, exact.finished_order)
        and np.array_equal(fast.n_preemptions, exact.n_preemptions)
        and np.array_equal(fast.n_iterations, exact.n_iterations)
        and np.allclose(fast.queuing_delay, exact.queuing_delay,
                        rtol=0, atol=0, equal_nan=True)
        and max_finish_delta == 0.0)
    row = {
        "cell": "fidelity_vs_exact",
        "scenario": "diurnal",
        "n_requests": n_slice,
        "seed": seed,
        "trace_identical": trace_identical,
        "jct_mean_delta_pct": round(float(mean_delta_pct), 6),
        "jct_p99_delta_pct": round(float(p99_delta_pct), 6),
        "max_finish_delta_s": max_finish_delta,
        "n_preemptions_fast": int(fast.n_preemptions.sum()),
        "n_preemptions_exact": int(exact.n_preemptions.sum()),
    }
    assert trace_identical, row
    assert mean_delta_pct <= 1.0, row  # the ISSUE's acceptance bound
    return row


def run(smoke: bool = False, quick: bool = False) -> List[Dict]:
    smoke = smoke or quick  # benchmarks.run harness passes quick=
    rows: List[Dict] = []
    if smoke:
        rows.append(scenario_cell("diurnal", 50_000, RATE))
        # a vectorized fast path clears thousands of req/s on any CPU;
        # dropping below this floor means an O(n^2) regression crept in
        assert rows[-1]["requests_per_s"] >= 500.0, rows[-1]
        rows.append(fidelity_cell(500))
    else:
        rows.append(scenario_cell("diurnal", 1_000_000, RATE))
        assert rows[-1]["wall_s"] < 600.0, (
            "1M requests must clear in under 10 minutes", rows[-1])
        rows.append(scenario_cell("multi_tenant_slo", 200_000, 0.8 * RATE))
        rows.append(scenario_cell("flash_crowd", 200_000, 0.8 * RATE))
        rows.append(fidelity_cell(2_000))
    save_results("sim_scale", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~50k-request slice, assertions only (CI guard)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    t0 = time.perf_counter()
    rows = run(smoke=args.smoke and not args.full)
    if not args.smoke:
        # regenerate the committed evidence only on a deliberate CLI run
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    for r in rows:
        if r["cell"].startswith("scale_"):
            print(f"[sim_scale] {r['scenario']:<16} n={r['n_requests']:<8} "
                  f"{r['wall_s']:.1f}s  {r['requests_per_s']:.0f} req/s  "
                  f"rss {r['peak_rss_mb']:.0f}MB  mean JCT {r['jct_mean']}s")
        else:
            print(f"[sim_scale] fidelity n={r['n_requests']}: "
                  f"trace_identical={r['trace_identical']}  "
                  f"mean-JCT delta {r['jct_mean_delta_pct']}%")
    print(f"[sim_scale] total {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
