"""Discrete-event cluster executor.

Implements the frontend's ``Backend`` ABC with virtual time and the
calibrated latency model.  Replays each job's pre-generated response token
stream (the simulator never invents tokens — ground truth lives with the
workload generator), tracks per-node KV residency for preemption/recompute
accounting, and enforces the Appendix-A memory capacity.

Clusters may be *heterogeneous*: ``node_profiles`` maps node ids to their
own :class:`~repro.simulate.profiles.ModelProfile` (e.g. fast and slow pods
mixing two calibrated entries); unmapped nodes fall back to ``profile``.
Each node's latency AND its Appendix-A KV capacity come from its own
profile, so placement policies are evaluated where nodes actually differ.
A job that resumes on a *different* node after preemption or migration is
simply not resident there — it pays the normal cold-start KV recompute.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.frontend import Backend, ExecResult
from repro.core.job import Job
from repro.simulate.profiles import SCHED_OVERHEAD_MS, ModelProfile


@dataclass
class SimExecutor(Backend):
    profile: ModelProfile
    #: include the paper's measured 11.04 ms scheduling overhead per iteration
    sched_overhead_s: float = SCHED_OVERHEAD_MS / 1000.0
    #: global cap on resident KV tokens per node; None = each node's own
    #: Appendix-A capacity (per-profile on heterogeneous clusters)
    kv_capacity_tokens: Optional[int] = None
    #: heterogeneous clusters: node id -> that pod's profile (latency and
    #: KV capacity); nodes absent from the map run ``profile``
    node_profiles: Optional[Dict[int, ModelProfile]] = None
    #: host<->device copy model for the KV swap tier (ALISE): one
    #: direction costs ``swap_latency_s + tokens * kv_bytes_per_token /
    #: swap_bandwidth_bytes_s``.  Defaults approximate a PCIe-4 x16 link.
    swap_bandwidth_bytes_s: float = 16e9
    swap_latency_s: float = 0.0005

    _resident: Dict[int, Set[int]] = field(default_factory=dict)
    _resident_tokens: Dict[int, Dict[int, int]] = field(default_factory=dict)
    #: node -> {job_id: KV tokens} stashed in host memory by ``offload``
    _swapped: Dict[int, Dict[int, int]] = field(default_factory=dict)
    #: swap-out seconds awaiting attribution to the node's next window
    _pending_swap_s: Dict[int, float] = field(default_factory=dict)
    mem_preemptions: int = 0
    #: context tokens re-prefilled by recompute-on-resume — the simulated
    #: counterpart of the live engine's ``resume_context_tokens`` (the
    #: preempt->resume cost-parity tests equate the two)
    recompute_prefill_tokens: int = 0
    n_swapouts: int = 0
    n_swapins: int = 0
    swapout_tokens: int = 0
    swapin_tokens: int = 0

    def __post_init__(self):
        if self.kv_capacity_tokens is None and not self.node_profiles:
            # homogeneous cluster: materialise the single capacity up front
            # (kept for introspection; heterogeneous runs stay per-node)
            self.kv_capacity_tokens = self.profile.kv_capacity_tokens()

    # ------------------------------------------------------------------ #
    def profile_of(self, node: int) -> ModelProfile:
        if self.node_profiles:
            return self.node_profiles.get(node, self.profile)
        return self.profile

    def node_token_cost(self, n_nodes: int) -> Dict[int, float]:
        """Seconds per generated token per node (batch-1 decode rate) — the
        calibrated cost map the ``least_eta`` placement policy consumes."""
        return {n: self.profile_of(n).decode_ms_1 / 1000.0
                for n in range(n_nodes)}

    def _capacity_of(self, node: int) -> int:
        if self.kv_capacity_tokens is not None:
            return self.kv_capacity_tokens
        return self.profile_of(node).kv_capacity_tokens()

    # ------------------------------------------------------------------ #
    def evict(self, node: int, job: Job) -> None:
        self._resident.setdefault(node, set()).discard(job.job_id)
        self._resident_tokens.setdefault(node, {}).pop(job.job_id, None)
        self._swapped.setdefault(node, {}).pop(job.job_id, None)
        # recompute eviction discards the KV: the job's next dispatch pays a
        # full re-prefill, and its scheduling debt reflects that
        job.prefilled_tokens = 0

    # ------------------------------------------------------------------ #
    # KV offload tier (Backend.offload / Backend.restore)
    # ------------------------------------------------------------------ #

    def _swap_cost_s(self, prof: ModelProfile, n_tokens: int) -> float:
        """One-direction host<->device copy time for ``n_tokens`` of KV."""
        return (self.swap_latency_s
                + n_tokens * prof.kv_bytes_per_token
                / self.swap_bandwidth_bytes_s)

    def offload(self, node: int, job: Job) -> bool:
        """Move the job's resident KV to host memory instead of discarding
        it; the copy time lands on the node's next window (the transfer
        occupies the device's DMA engines, not the caller's clock)."""
        res_toks = self._resident_tokens.setdefault(node, {})
        n = res_toks.get(job.job_id)
        if n is None:
            return False
        self._swapped.setdefault(node, {})[job.job_id] = n
        self._resident.setdefault(node, set()).discard(job.job_id)
        res_toks.pop(job.job_id)
        self._pending_swap_s[node] = (
            self._pending_swap_s.get(node, 0.0)
            + self._swap_cost_s(self.profile_of(node), n))
        self.n_swapouts += 1
        self.swapout_tokens += n
        return True

    def restore(self, node: int, job: Job) -> bool:
        """Explicit swap-in (execute() also restores lazily on dispatch)."""
        n = self._swapped.setdefault(node, {}).pop(job.job_id, None)
        if n is None:
            return False
        self._resident.setdefault(node, set()).add(job.job_id)
        self._resident_tokens.setdefault(node, {})[job.job_id] = n
        self._pending_swap_s[node] = (
            self._pending_swap_s.get(node, 0.0)
            + self._swap_cost_s(self.profile_of(node), n))
        self.n_swapins += 1
        self.swapin_tokens += n
        return True

    def preempt_costs(self, node: int, job: Job
                      ) -> Optional[Tuple[float, float]]:
        """(swap_round_trip_s, recompute_s) for the ``auto`` break-even:
        two copies of the job's current KV footprint vs a batch-1
        re-prefill of the same context through the latency model."""
        n = job.prefilled_tokens
        if n <= 0:
            return None
        prof = self.profile_of(node)
        swap_s = 2.0 * self._swap_cost_s(prof, n)
        rec_s = prof.prefill_ms(1, n) / 1000.0
        return swap_s, rec_s

    def counters(self) -> Dict[str, int]:
        return {
            "recompute_prefill_tokens": self.recompute_prefill_tokens,
            "swapouts": self.n_swapouts, "swapins": self.n_swapins,
            "swapout_tokens": self.swapout_tokens,
            "swapin_tokens": self.swapin_tokens,
            "mem_preemptions": self.mem_preemptions,
        }

    def resident_token_count(self, node: int) -> int:
        return sum(self._resident_tokens.get(node, {}).values())

    def capacity(self, node: int) -> Optional[int]:
        # job count is unbounded in the simulator; residency is bounded by
        # KV *tokens* (Appendix-A memory model), enforced inside execute()
        return None

    def free_capacity(self, node: int) -> Optional[int]:
        return None

    @staticmethod
    def _chunk_goal(job: Job) -> int:
        """Context tokens a chunked prefill must materialise before ``job``
        decodes — mirrors the live engine's ``_resume_tokens``: the prompt
        for a fresh job, ``prompt + generated[:-1]`` for a resumed one (the
        last emitted token seeds decode; its KV is written by the first
        decode step).  Monotone under decode progress, so a job that
        completed prefill stays complete as it generates."""
        plen = len(job.prompt_tokens)
        return plen + job.tokens_generated - 1 if job.tokens_generated \
            else plen

    # ------------------------------------------------------------------ #
    def execute(self, node: int, jobs: Sequence[Job], window: int,
                now: float, prefill_chunk: Optional[int] = None
                ) -> ExecResult:
        prof = self.profile_of(node)
        res = self._resident.setdefault(node, set())
        res_toks = self._resident_tokens.setdefault(node, {})
        swapped = self._swapped.setdefault(node, {})
        b = len(jobs)
        chunked = prefill_chunk is not None
        extra = self._pending_swap_s.pop(node, 0.0)

        prefill_ms = 0.0
        for job in jobs:
            if job.job_id in swapped:
                # swap-in: the KV comes back from host memory — copy time
                # instead of recompute, and the prefill cursor survives
                n = swapped.pop(job.job_id)
                res.add(job.job_id)
                res_toks[job.job_id] = n
                extra += self._swap_cost_s(prof, n)
                self.n_swapins += 1
                self.swapin_tokens += n
            elif job.job_id not in res:
                # cold start or resumed-after-preemption/migration: recompute
                # the KV cache for everything generated so far (vLLM
                # recompute mode)
                n = len(job.prompt_tokens) + job.tokens_generated
                if job.tokens_generated > 0:
                    # mirrors the engine's resume_context_tokens: a fresh
                    # job's first prefill is not a recompute charge
                    self.recompute_prefill_tokens += n
                res.add(job.job_id)
                if chunked:
                    # chunk admission: KV materialises chunk by chunk below
                    res_toks[job.job_id] = 0
                    job.prefilled_tokens = 0
                else:
                    prefill_ms += prof.prefill_ms(b, n)
                    res_toks[job.job_id] = n
                    job.prefilled_tokens = n

        # decode eligibility is decided BEFORE the chunk advances (the live
        # engine partitions the batch the same way): a job completing its
        # final chunk this window starts decoding next window
        if chunked:
            eligible = [j for j in jobs
                        if j.prefilled_tokens >= self._chunk_goal(j)]
            incomplete = [j for j in jobs
                          if j.prefilled_tokens < self._chunk_goal(j)]
            if incomplete:
                # at most ONE batch-1 chunk per window, first incomplete
                # job in batch order — exactly the engine's dispatch
                j0 = incomplete[0]
                n_c = min(prefill_chunk,
                          self._chunk_goal(j0) - j0.prefilled_tokens)
                prefill_ms += prof.prefill_ms(1, n_c)
                j0.prefilled_tokens += n_c
                res_toks[j0.job_id] = j0.prefilled_tokens
        else:
            eligible = list(jobs)
        elig_ids = {j.job_id for j in eligible}

        tokens_out: List[List[int]] = []
        finished: List[bool] = []
        max_new = 0
        for job in jobs:
            if len(job.output_tokens) < job.true_output_len:
                # the simulator REPLAYS ground-truth streams — a job whose
                # stream is shorter than its declared length would stop
                # progressing once the stream runs dry and spin the event
                # loop forever; fail loudly instead (the live engine has no
                # such requirement: it invents tokens)
                raise ValueError(
                    f"job {job.job_id}: output_tokens has "
                    f"{len(job.output_tokens)} tokens but true_output_len="
                    f"{job.true_output_len}; the simulator cannot replay it "
                    "(use repro.data.workload streams or fill output_tokens)")
            if job.job_id not in elig_ids:
                # mid-prefill: no decode participation, no emission
                tokens_out.append([])
                finished.append(False)
                continue
            remaining = job.true_output_len - job.tokens_generated
            n_new = min(window, remaining)
            start = job.tokens_generated
            tokens_out.append(job.output_tokens[start : start + n_new])
            finished.append(n_new >= remaining)
            job.prefilled_tokens = (len(job.prompt_tokens)
                                    + job.tokens_generated + n_new)
            # residency tracks the cursor exactly (``offload`` stashes this
            # count, ``preempt_costs`` prices it — they must agree)
            res_toks[job.job_id] = job.prefilled_tokens
            max_new = max(max_new, n_new)

        # chunked windows decode only the eligible sub-batch (the engine's
        # compacted dispatch); the unchunked arithmetic is bit-identical to
        # the pre-chunking model
        b_dec = len(eligible) if chunked else b
        decode_ms = max_new * prof.decode_ms(b_dec) if b_dec else 0.0
        duration = self.sched_overhead_s + (prefill_ms + decode_ms) / 1000.0
        if extra:
            duration += extra

        # Appendix-A memory pressure: if resident KV exceeds capacity, evict
        # the largest non-batch residents (counted as memory preemptions)
        cap = self._capacity_of(node)
        total = sum(res_toks.values())
        if total > cap:
            batch_ids = {j.job_id for j in jobs}
            evictable = sorted(
                ((t, jid) for jid, t in res_toks.items()
                 if jid not in batch_ids),
                reverse=True,
            )
            for t, jid in evictable:
                if total <= cap:
                    break
                res.discard(jid)
                res_toks.pop(jid)
                total -= t
                self.mem_preemptions += 1

        return ExecResult(duration, tokens_out, finished)
