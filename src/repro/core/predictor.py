"""Response-length predictors (paper §3.2–3.3, §4.2).

Three implementations behind one protocol:

* :class:`BGEPredictor` — the paper's model: a (frozen) BGE-style encoder +
  8 fully-connected layers (hidden 1024, ReLU) regressing the *remaining*
  output length from ``[CLS] prompt [SEP] partial-output``.  Implemented and
  trained fully in JAX; the encoder can be frozen (paper §3.2) or trained
  end-to-end (our beyond-paper variant — the synthetic encoder is not
  pretrained, so unfreezing is what makes it "fine-tuned").
* :class:`OraclePredictor` — returns the ground-truth remaining length
  (the paper's SJF "ideal" upper bound).
* :class:`NoisyOraclePredictor` — truth corrupted by step-dependent
  lognormal noise whose σ decays with the iteration index, calibrated to the
  paper's Fig. 2(b) MAE-vs-step curve.  Used by the cluster simulator where
  running the real encoder for every virtual request would dominate runtime.

``Predictor.init(job)`` / ``Predictor.iter(job)`` mirror Algorithm 1
lines 11–14.  The scheduler's hot path goes through the batched
``predict_jobs`` instead: one *shape-bucketed* dispatch per scheduling
window (batch padded to power-of-two buckets, sequence to the
``seq_bucket`` ladder) so the jitted apply compiles once per bucket —
``BGEPredictor.num_traces`` exposes the compile count, and
``num_dispatches`` the dispatch count, for the recompile-storm guard in
``benchmarks/scheduler_overhead.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.job import Job
from repro.data.dataset import (
    WINDOW,
    StepSample,
    batch_bucket,
    pad_batch,
    seq_bucket,
)
from repro.data.tokenizer import CLS_ID, SEP_ID
from repro.models import encoder as E
from repro.models.layers import dense_init
from repro.training import AdamWConfig, train


class Predictor(Protocol):
    def init(self, job: Job) -> float: ...
    def iter(self, job: Job) -> float: ...


# --------------------------------------------------------------------------- #
# Oracle predictors
# --------------------------------------------------------------------------- #


class OraclePredictor:
    """Ground-truth remaining length (the SJF 'ideal' bound)."""

    def init(self, job: Job) -> float:
        return float(job.true_remaining)

    def iter(self, job: Job) -> float:
        return float(job.true_remaining)


@dataclass
class NoisyOraclePredictor:
    """truth * lognormal(0, sigma_k);  sigma_k = sigma0 * decay^k.

    Defaults calibrated against our trained BGE predictor's per-step relative
    error (see benchmarks/fig2_iterative_mae.py): step-0 MAE/mean ≈ 0.45
    falling toward ≈ 0.25 by step 4 — matching the paper's Fig. 2(b) shape.
    """

    # calibrated to the trained BGE predictor's relative error per step
    # (benchmarks/fig2_iterative_mae.py): ~0.5 at step 0 -> ~0.3 floor
    sigma0: float = 0.50
    decay: float = 0.90
    sigma_floor: float = 0.30
    seed: int = 0
    _rng: np.random.RandomState = field(default=None, repr=False)

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    def _sigma(self, step: int) -> float:
        return max(self.sigma0 * self.decay ** step, self.sigma_floor)

    def _predict(self, job: Job) -> float:
        step = job.tokens_generated // WINDOW
        s = self._sigma(step)
        noise = self._rng.lognormal(mean=-0.5 * s * s, sigma=s)
        return max(float(job.true_remaining) * noise, 1.0)

    init = _predict
    iter = _predict


# --------------------------------------------------------------------------- #
# BGE predictor (the paper's model)
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class PredictorConfig:
    encoder: E.EncoderArchConfig = E.EncoderArchConfig()
    n_fc_layers: int = 8           # paper: eight FC layers
    fc_hidden: int = 1024          # paper: hidden dim 1024
    max_len: int = 256
    freeze_encoder: bool = False   # paper freezes pretrained BGE; ours trains
    lr: float = 1e-4               # paper: 1e-4
    predict_log: bool = True       # regress log(remaining) (skew-friendly)


def init_head(key, in_dim: int, hidden: int, n_layers: int,
              init_log_len: float = 4.8) -> Dict:
    """8-FC regression head.  The final bias starts at log(median length)
    (~e^4.8 ≈ 120 tokens) so the log-space prediction begins at a sane prior
    and gradients flow from step 0 (a zero-init bias puts every prediction at
    the clip boundary, where the gradient dies)."""
    ks = jax.random.split(key, n_layers)
    layers = []
    d = in_dim
    for i in range(n_layers - 1):
        layers.append({"w": dense_init(ks[i], d, hidden),
                       "b": jnp.zeros((hidden,))})
        d = hidden
    layers.append({"w": dense_init(ks[-1], d, 1),
                   "b": jnp.full((1,), init_log_len)})
    return {"layers": layers}


def apply_head(head: Dict, x: jnp.ndarray) -> jnp.ndarray:
    for lp in head["layers"][:-1]:
        x = jax.nn.relu(x @ lp["w"] + lp["b"])
    last = head["layers"][-1]
    return (x @ last["w"] + last["b"])[..., 0]


class BGEPredictor:
    """Encoder + FC-head length regressor with iterative refinement."""

    def __init__(self, cfg: PredictorConfig = PredictorConfig(), seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        self.params = {
            "encoder": E.init_encoder(k1, cfg.encoder),
            # paper §4.2: mean-pooled token embeddings feed the FC stack;
            # we concat [CLS; mean] (CLS is what §3.2 probes)
            "head": init_head(k2, 2 * cfg.encoder.d_model, cfg.fc_hidden,
                              cfg.n_fc_layers),
        }
        self._n_traces = 0
        self.num_dispatches = 0
        self._apply = jax.jit(self._apply_fn)

    @property
    def num_traces(self) -> int:
        """XLA traces of the *current* jitted apply — the compile-count
        introspection hook.  Incremented by the Python side effect in
        ``_apply_fn`` (which runs only while JAX traces a new input shape)
        and reset whenever ``fit`` re-jits the apply, so for a predictor
        doing serving-path inference it stays <= the number of shape
        buckets no matter how the scheduling pool grows.  ``evaluate``
        drives its own (unbucketed) chunk shapes and adds their traces."""
        return self._n_traces

    # -------------------------------------------------------------- #
    def _apply_fn(self, params, tokens, mask):
        self._n_traces += 1  # Python side effect: runs once per trace
        cls, mean = E.encode(params["encoder"], self.cfg.encoder, tokens, mask)
        feats = jnp.concatenate([cls, mean], axis=-1)
        raw = apply_head(params["head"], feats)
        if self.cfg.predict_log:
            # wide clip: the gradient must not die at init (raw ≈ prior)
            return jnp.exp(jnp.clip(raw, -2.0, 8.0))  # e^8 ≈ 3k > MAX_OUTPUT
        return jnp.maximum(raw, 1.0)

    def predict_tokens(self, token_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """One batched inference dispatch, shape-bucketed.

        The batch dimension is padded to the next power of two and the
        sequence dimension to the ``seq_bucket`` ladder (capped at
        ``max_len``), so the jitted apply compiles once per (batch, seq)
        bucket instead of once per raw pool shape.  Padding rows are fully
        masked (the encoder's masked attention/pooling make them inert) and
        sliced off before returning."""
        ml = self.cfg.max_len
        b = len(token_lists)
        if b == 0:
            return np.zeros((0,))
        self.num_dispatches += 1
        longest = max(min(len(t), ml) for t in token_lists)
        bb = batch_bucket(b)
        sl = seq_bucket(longest, ml)
        toks = np.zeros((bb, sl), np.int32)
        mask = np.zeros((bb, sl), bool)
        for i, t in enumerate(token_lists):
            t = list(t)[:sl]
            toks[i, : len(t)] = t
            mask[i, : len(t)] = True
        return np.asarray(self._apply(self.params, toks, mask))[:b]

    # -------------------------------------------------------------- #
    def _job_input(self, job: Job) -> List[int]:
        from repro.data.dataset import clip_step_input

        return clip_step_input(job.prompt_tokens, job.generated,
                               self.cfg.max_len)

    def init(self, job: Job) -> float:
        return float(self.predict_tokens([self._job_input(job)])[0])

    def iter(self, job: Job) -> float:
        return float(self.predict_tokens([self._job_input(job)])[0])

    def predict_jobs(self, jobs: Sequence[Job]) -> np.ndarray:
        """Batched prediction for a whole pool (one encoder call)."""
        if not jobs:
            return np.zeros((0,))
        return self.predict_tokens([self._job_input(j) for j in jobs])

    # -------------------------------------------------------------- #
    def loss_fn(self, params, batch):
        pred = self._apply_fn(params, batch["tokens"], batch["mask"])
        target = batch["labels"]
        if self.cfg.predict_log:
            err = jnp.log(pred) - jnp.log(jnp.maximum(target, 1.0))
        else:
            err = (pred - target) / 100.0
        # Huber for robustness against the long tail
        huber = jnp.where(jnp.abs(err) < 1.0, 0.5 * err * err,
                          jnp.abs(err) - 0.5)
        mae = jnp.mean(jnp.abs(pred - target))
        return jnp.mean(huber), {"mae": mae}

    def fit(self, train_samples: List[StepSample], *, num_steps: int = 600,
            batch_size: int = 32, log_fn=None) -> Dict:
        from repro.data.dataset import batch_iterator

        mask = None
        if self.cfg.freeze_encoder:
            mask = {
                "encoder": jax.tree_util.tree_map(lambda _: False,
                                                  self.params["encoder"]),
                "head": jax.tree_util.tree_map(lambda _: True,
                                               self.params["head"]),
            }
        it = batch_iterator(train_samples, batch_size, self.cfg.max_len)
        opt = AdamWConfig(lr=self.cfg.lr, warmup_steps=max(num_steps // 20, 1),
                          total_steps=num_steps, weight_decay=0.01)
        self.params, history = train(
            self.params, self.loss_fn, it, opt, num_steps=num_steps,
            trainable_mask=mask, log_every=max(num_steps // 10, 1),
            log_fn=log_fn,
        )
        self._apply = jax.jit(self._apply_fn)
        # fresh jit cache -> fresh compile count (training traced
        # _apply_fn under its own jit; those compiles are gone now)
        self._n_traces = 0
        return history

    # -------------------------------------------------------------- #
    def evaluate(self, samples: List[StepSample]) -> Dict[str, float]:
        """MAE / RMSE / R² — the paper's Table 2 metrics."""
        if not samples:
            return {"mae": float("nan"), "rmse": float("nan"), "r2": float("nan")}
        batch = pad_batch(samples, self.cfg.max_len)
        preds = []
        for i in range(0, len(samples), 256):
            preds.append(
                np.asarray(
                    self._apply(self.params, batch["tokens"][i : i + 256],
                                batch["mask"][i : i + 256])
                )
            )
        pred = np.concatenate(preds)
        y = batch["labels"][: len(pred)]
        mae = float(np.mean(np.abs(pred - y)))
        rmse = float(np.sqrt(np.mean((pred - y) ** 2)))
        ss_res = float(np.sum((pred - y) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        r2 = 1.0 - ss_res / max(ss_tot, 1e-9)
        return {"mae": mae, "rmse": rmse, "r2": r2}

    def evaluate_per_step(self, samples: List[StepSample],
                          max_step: int = 6) -> Dict[int, float]:
        """MAE bucketed by iteration index — the paper's Fig. 2(b)."""
        out = {}
        for k in range(max_step):
            sub = [s for s in samples if s.step == k]
            if len(sub) >= 5:
                out[k] = self.evaluate(sub)["mae"]
        return out
