"""Generic training loop used by both the LM example and the predictor."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
)


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    *,
    trainable_mask: Optional[Any] = None,
    donate: bool = True,
):
    """loss_fn(params, batch) -> (loss, metrics_dict).

    Returns jitted ``step(params, opt_state, batch) ->
    (params, opt_state, metrics)``.
    """

    def step(params, opt_state: AdamWState, batch):
        (loss, inner), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params, trainable_mask=trainable_mask
        )
        metrics = {"loss": loss, **inner, **opt_metrics}
        return params, opt_state, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


def train(
    params: Any,
    loss_fn: Callable,
    data_iter: Iterable,
    opt_cfg: AdamWConfig,
    *,
    num_steps: int,
    trainable_mask: Optional[Any] = None,
    log_every: int = 50,
    log_fn: Callable[[int, Dict], None] = None,
) -> Tuple[Any, Dict]:
    """Run ``num_steps`` of AdamW over ``data_iter``.  Returns
    (params, history) where history maps step -> host metrics."""
    step_fn = make_train_step(loss_fn, opt_cfg, trainable_mask=trainable_mask)
    opt_state = adamw_init(params)
    history: Dict[int, Dict] = {}
    t0 = time.time()
    for i in range(num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == num_steps - 1:
            host = {k: float(v) for k, v in metrics.items()}
            host["wall_s"] = time.time() - t0
            history[i] = host
            if log_fn:
                log_fn(i, host)
    return params, history
