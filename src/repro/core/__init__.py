"""ELIS core: the paper's contribution (ISRTF + iterative length predictor).

Public serving surface (``repro.core.api``): ``ElisServer`` + the typed
request lifecycle (``Request``/``RequestOptions``/``TokenChunk``/``Response``/
``RequestStatus``).  Scheduler internals (``Job``, ``ELISFrontend``) remain
importable for tests and benchmarks but are not part of the caller contract.
"""
from repro.core.job import Job, JobState, TERMINAL_STATES
from repro.core.load_balancer import (
    GlobalState,
    LoadBalancer,
    PLACEMENTS,
    PlacementPolicy,
    make_placement,
)
from repro.core.metrics import (
    improvement,
    kendall_tau,
    prediction_stats,
    summarize,
)
from repro.core.predictor import (
    BGEPredictor,
    CalibrationConfig,
    ConformalPredictor,
    EMADebiasedPredictor,
    LengthPrediction,
    LengthPredictor,
    NoisyOraclePredictor,
    OraclePredictor,
    PredictorConfig,
    RankedPredictor,
    RankingConfig,
    make_predictor,
    predict_lengths,
    wrap_calibration,
)
from repro.core.scheduler import (
    PREEMPT_POLICIES,
    PreemptionConfig,
    PriorityBuffer,
    SchedulerConfig,
    make_policy,
    select_preemptions,
)
from repro.core.frontend import (
    Backend,
    ELISFrontend,
    Event,
    ExecResult,
    FrontendConfig,
)
from repro.core.api import (
    ElisServer,
    Request,
    RequestHandle,
    RequestOptions,
    RequestStatus,
    Response,
    TokenChunk,
)

#: deprecated alias — the structural ``Executor`` Protocol duplicated the
#: ``Backend`` ABC since PR 1; implement/annotate against ``Backend``
Executor = Backend

__all__ = [
    "BGEPredictor",
    "Backend",
    "CalibrationConfig",
    "ConformalPredictor",
    "ELISFrontend",
    "EMADebiasedPredictor",
    "ElisServer",
    "Event",
    "ExecResult",
    "Executor",
    "FrontendConfig",
    "GlobalState",
    "Job",
    "JobState",
    "LengthPrediction",
    "LengthPredictor",
    "LoadBalancer",
    "NoisyOraclePredictor",
    "OraclePredictor",
    "PLACEMENTS",
    "PREEMPT_POLICIES",
    "PlacementPolicy",
    "PredictorConfig",
    "PreemptionConfig",
    "PriorityBuffer",
    "RankedPredictor",
    "RankingConfig",
    "Request",
    "RequestHandle",
    "RequestOptions",
    "RequestStatus",
    "Response",
    "SchedulerConfig",
    "TERMINAL_STATES",
    "TokenChunk",
    "improvement",
    "kendall_tau",
    "make_placement",
    "make_policy",
    "make_predictor",
    "predict_lengths",
    "prediction_stats",
    "select_preemptions",
    "summarize",
    "wrap_calibration",
]
