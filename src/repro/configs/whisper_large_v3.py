"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.

Decoder: 32L, d_model 1280, 20 heads (MHA: kv=20), d_ff 5120, vocab 51866,
learned positions, LayerNorm + GELU (non-gated MLP).  Encoder: 32L over 1500
frame positions.  The mel-spectrogram + conv frontend is a STUB per the repro
spec — ``input_specs`` provides precomputed frame embeddings
``(batch, 1500, d_model)``.

long_500k is SKIPPED for this arch (decoder positions architecturally bounded
at 448; see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import EncoderConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="whisper-large-v3",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        norm="layernorm",
        activation="gelu",
        gated_mlp=False,
        rope_type="learned",
        tie_embeddings=True,
        attention_type="full",
        long_context_mode="unsupported",
        encoder=EncoderConfig(n_layers=32, n_frames=1500),
        frontend="audio_stub",
        frontend_tokens=1500,
        max_position_embeddings=448,
    )
)
