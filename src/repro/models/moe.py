"""Mixture-of-Experts FFN (Mixtral / Qwen2-MoE style).

TPU adaptation: expert dispatch is expressed as dense einsums over a
``(tokens, experts)`` combine matrix rather than gather/scatter, which maps
onto the MXU and shards cleanly with experts on the ``model`` mesh axis
(expert parallelism).  The router aux loss follows the Switch/Mixtral
load-balancing formulation.

Two paths:
  * ``moe_block_dense`` — einsum dispatch, every expert computes every token
    masked by combine weights.  Exact, differentiable, used for training and
    for the dry-run (XLA shards the expert axis; tokens are NOT replicated
    per-expert in memory thanks to the contracting einsum).
  * ``moe_block_grouped`` — top-k gather + segment compute; cheaper on small
    decode batches.  Used by the engine on CPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    e = m.num_experts

    def stack_init(k, shape):
        return jax.random.uniform(k, shape, dtype, -1.0, 1.0) / jnp.sqrt(
            jnp.asarray(shape[-2], dtype)
        )

    p: Params = {
        "router": dense_init(ks[0], d, e, dtype),
        "w_gate": stack_init(ks[1], (e, d, m.expert_d_ff)),
        "w_up": stack_init(ks[2], (e, d, m.expert_d_ff)),
        "w_down": stack_init(ks[3], (e, m.expert_d_ff, d)),
    }
    if m.num_shared_experts:
        sk = jax.random.split(ks[4], 4)
        p["shared"] = {
            "w_gate": dense_init(sk[0], d, m.shared_d_ff, dtype),
            "w_up": dense_init(sk[1], d, m.shared_d_ff, dtype),
            "w_down": dense_init(sk[2], m.shared_d_ff, d, dtype),
            # qwen2-moe gates the shared path with a sigmoid scalar gate
            "gate": dense_init(sk[3], d, 1, dtype),
        }
    return p


def router_probs(p: Params, cfg, x: jnp.ndarray):
    """x (T, d) -> (probs (T, E), topk_weights (T, K), topk_idx (T, K))."""
    m = cfg.moe
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_i = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk_prob:
        topk_w = topk_w / jnp.clip(jnp.sum(topk_w, -1, keepdims=True), 1e-9)
    return probs, topk_w, topk_i


def load_balance_loss(probs: jnp.ndarray, topk_i: jnp.ndarray, num_experts: int):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[topk_i.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(T * topk_i.shape[-1], 1)
    frac_probs = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_probs)


def moe_block_dense(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-dispatch MoE.  x (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, topk_w, topk_i = router_probs(p, cfg, xt)
    # combine[t, e] = routing weight of expert e for token t (0 if unrouted)
    combine = jnp.zeros((b * s, m.num_experts), xt.dtype)
    combine = combine.at[jnp.arange(b * s)[:, None], topk_i].set(
        topk_w.astype(xt.dtype)
    )
    # Expert compute: contract tokens against each expert's weights, weight by
    # combine.  einsum keeps the expert axis explicit -> shards on "model".
    h_gate = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    h_up = jnp.einsum("td,edf->tef", xt, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out = jnp.einsum("tef,efd,te->td", h, p["w_down"], combine)
    aux = load_balance_loss(probs, topk_i, m.num_experts)
    out = out.reshape(b, s, d)
    if "shared" in p:
        sp = p["shared"]
        sh = (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
        gate = jax.nn.sigmoid(x @ sp["gate"])
        out = out + gate * sh
    return out, aux


def moe_block_grouped(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather-based top-k MoE for small batches (decode path).

    Computes only the selected experts per token via vmapped gather of expert
    weights.  Numerically identical to the dense path.
    """
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, topk_w, topk_i = router_probs(p, cfg, xt)

    def per_token(xv, wks, iks):
        wg = p["w_gate"][iks]  # (K, d, f)
        wu = p["w_up"][iks]
        wd = p["w_down"][iks]  # (K, f, d)
        h = jax.nn.silu(jnp.einsum("d,kdf->kf", xv, wg)) * jnp.einsum(
            "d,kdf->kf", xv, wu
        )
        y = jnp.einsum("kf,kfd->kd", h, wd)
        return jnp.sum(y * wks[:, None].astype(y.dtype), axis=0)

    out = jax.vmap(per_token)(xt, topk_w, topk_i).reshape(b, s, d)
    aux = load_balance_loss(probs, topk_i, m.num_experts)
    if "shared" in p:
        sp = p["shared"]
        sh = (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
        gate = jax.nn.sigmoid(x @ sp["gate"])
        out = out + gate * sh
    return out, aux


def moe_block(p: Params, cfg, x: jnp.ndarray, *, impl: str = "dense"):
    if impl == "grouped":
        return moe_block_grouped(p, cfg, x)
    return moe_block_dense(p, cfg, x)
