"""Serving launcher — the Kubernetes-pod entrypoint analogue.

Assembles the full ELIS stack from CLI args: N backend workers (each an
InferenceEngine on the selected ``--arch``, reduced configs on CPU), the
frontend scheduler with the chosen policy, and either a trace file from
``repro.launch.generate`` or a synthetic stream.

    python -m repro.launch.serve --arch qwen2-1.5b --policy isrtf \
        --workers 2 --trace trace.jsonl
    python -m repro.launch.serve --arch mamba2-130m --policy isrtf --n 12
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import (
    BGEPredictor,
    ELISFrontend,
    FrontendConfig,
    Job,
    OraclePredictor,
    PredictorConfig,
    PreemptionConfig,
    SchedulerConfig,
    summarize,
)
from repro.data import GammaArrivals, WorkloadGenerator
from repro.engine import EngineConfig, EngineExecutor, InferenceEngine
from repro.models import init_params
from repro.models.encoder import EncoderArchConfig
from repro.training import latest_step, restore_checkpoint


def load_jobs(args):
    if args.trace:
        jobs = []
        for line in open(args.trace):
            r = json.loads(line)
            jobs.append(Job(
                job_id=r["request_id"], prompt=r["prompt"],
                prompt_tokens=r["prompt_tokens"],
                arrival_time=r["arrival_time"],
                true_output_len=min(r.get("max_tokens", args.max_output),
                                    args.max_output),
            ))
        return jobs
    gen = WorkloadGenerator(seed=args.seed)
    rng = np.random.RandomState(args.seed)
    times = GammaArrivals().rate_scaled(args.rate).sample_arrival_times(
        args.n, rng)
    jobs = []
    for i, t in enumerate(times):
        r = gen.sample_request()
        jobs.append(Job(job_id=i, prompt=r.prompt,
                        prompt_tokens=r.prompt_tokens,
                        arrival_time=float(t),
                        true_output_len=min(r.true_output_len,
                                            args.max_output)))
    return jobs


def build_predictor(args):
    if args.predictor == "oracle":
        return OraclePredictor()
    cfg = PredictorConfig(
        encoder=EncoderArchConfig(d_model=128, n_heads=4, n_layers=3,
                                  d_ff=256, max_len=192),
        n_fc_layers=8, fc_hidden=256, max_len=192,
    )
    pred = BGEPredictor(cfg, seed=0)
    if args.predictor_ckpt:
        step = latest_step(args.predictor_ckpt)
        if step is None:
            sys.exit(f"no checkpoint in {args.predictor_ckpt}")
        pred.params, _ = restore_checkpoint(args.predictor_ckpt, step,
                                            pred.params)
    return pred


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(list_archs()))
    ap.add_argument("--policy", default="isrtf",
                    choices=["fcfs", "sjf", "isrtf", "mlfq"])
    ap.add_argument("--predictor", default="oracle",
                    choices=["oracle", "bge"])
    ap.add_argument("--predictor-ckpt", default=None,
                    help="restore a trained BGE predictor (train_predictor.py)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--max-output", type=int, default=32)
    ap.add_argument("--trace", default=None)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--rate", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-preemption", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"[serve] {args.workers} worker(s) x {args.slots} slots, "
          f"{cfg.arch_id}, policy={args.policy}", file=sys.stderr)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engines = {
        n: InferenceEngine(cfg, params, EngineConfig(
            max_slots=args.slots, max_len=512, max_output=args.max_output,
            eos_id=-1, respect_job_max=True))
        for n in range(args.workers)
    }
    predictor = (None if args.policy in ("fcfs", "mlfq")
                 else build_predictor(args))
    frontend = ELISFrontend(
        FrontendConfig(
            n_nodes=args.workers,
            scheduler=SchedulerConfig(policy=args.policy, window=args.window,
                                      batch_size=args.slots),
            preemption=PreemptionConfig(enabled=not args.no_preemption),
        ),
        predictor,
        EngineExecutor(engines),
    )
    jobs = load_jobs(args)
    for j in jobs:
        frontend.submit(j)
    done = frontend.run()
    for j in sorted(done, key=lambda j: j.job_id):
        print(json.dumps({
            "request_id": j.job_id,
            "node": j.node,
            "n_tokens": j.tokens_generated,
            "jct_s": round(j.jct(), 3),
            "queuing_delay_s": round(j.queuing_delay, 3),
            "preemptions": j.n_preemptions,
        }))
    m = summarize(done)
    print(f"[serve] mean JCT {m['jct_mean']:.2f}s  queue "
          f"{m['queuing_delay_mean']:.2f}s  throughput "
          f"{m['throughput_rps']:.2f} req/s", file=sys.stderr)


if __name__ == "__main__":
    main()
