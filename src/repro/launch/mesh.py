"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

TPU v5e hardware constants used by the roofline analysis live here too.
"""
from __future__ import annotations

import numpy as np

import jax

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_mesh(shape, axes=("data", "model"), *, devices=None):
    """Arbitrary (small) device meshes — e.g. ``(2, 4)`` data×model on a
    host forced to 8 CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    ``devices`` defaults to ``jax.devices()``; the first ``prod(shape)``
    are used, so disjoint sub-clusters can be carved by passing explicit
    device slices."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} / axes {tuple(axes)} mismatch")
    n = int(np.prod(shape))
    devices = list(jax.devices() if devices is None else devices)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before jax "
            "initialises (see repro.launch.dryrun)"
        )
    try:
        return jax.make_mesh(shape, tuple(axes), devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.asarray(devices[:n]).reshape(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def pod_meshes(mesh):
    """Split a (…, data, model) mesh into independent single-axis
    ``("model",)`` meshes, one per data row — the serving topology: each
    data-parallel pod is a tensor-parallel island (no collective ever
    crosses pods; the frontend places whole requests on one pod)."""
    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'model' axis: {mesh.axis_names}")
    tp = int(mesh.devices.shape[list(mesh.axis_names).index("model")])
    rows = np.asarray(mesh.devices).reshape(-1, tp)
    return [make_mesh((tp,), ("model",), devices=list(row)) for row in rows]


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
