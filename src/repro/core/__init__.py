"""ELIS core: the paper's contribution (ISRTF + iterative length predictor)."""
from repro.core.job import Job, JobState
from repro.core.load_balancer import GlobalState, LoadBalancer
from repro.core.metrics import improvement, summarize
from repro.core.predictor import (
    BGEPredictor,
    NoisyOraclePredictor,
    OraclePredictor,
    PredictorConfig,
)
from repro.core.scheduler import (
    PreemptionConfig,
    PriorityBuffer,
    SchedulerConfig,
    make_policy,
    select_preemptions,
)
from repro.core.frontend import ELISFrontend, ExecResult, FrontendConfig

__all__ = [
    "BGEPredictor",
    "ELISFrontend",
    "ExecResult",
    "FrontendConfig",
    "GlobalState",
    "Job",
    "JobState",
    "LoadBalancer",
    "NoisyOraclePredictor",
    "OraclePredictor",
    "PredictorConfig",
    "PreemptionConfig",
    "PriorityBuffer",
    "SchedulerConfig",
    "improvement",
    "make_policy",
    "select_preemptions",
    "summarize",
]
